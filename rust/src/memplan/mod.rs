//! Memory planner — the deployment-framework component behind Figs. 4c/4d
//! and the memory half of Fig. 9.
//!
//! The paper's framework needs three memory segments (§IV-A):
//!
//!  1. **feature RAM** — an arena holding activations/errors. For plain
//!     inference consecutive tensors can reuse heap aggressively; training
//!     extends lifetimes (Fig. 1's data dependencies: a trainable layer's
//!     *input* must survive until its backward step, ReLU outputs are
//!     needed for masking, pool argmaxes for routing), so reuse
//!     opportunities shrink — exactly the effect the paper describes.
//!  2. **weight RAM** — trainable weights (they are written at runtime so
//!     they cannot stay in Flash) plus gradient-accumulation buffers and
//!     optimizer statistics.
//!  3. **Flash** — frozen weights and the runtime image.
//!
//! The planner performs a lifetime analysis over the fwd+bwd schedule and a
//! greedy best-fit arena allocation (size-descending first fit — the
//! standard offline dynamic-storage-allocation heuristic used by MCU
//! inference libraries [2], [3]).
//!
//! It also provides the [`Scratch`] arena backing the im2col/GEMM execution
//! engine (`kernels::gemm`): one growable set of packing/accumulator
//! buffers, sized once per model and reused across every conv call of a
//! forward pass instead of being reallocated per layer.

use crate::graph::{DnnConfig, LayerKind, ModelDef, Precision};

/// Exact scratch requirements of a compiled execution plan: the union of
/// every buffer request the plan's ops can make, per backing buffer.
/// Computed by `graph::plan::ExecPlan::compile` (which knows each layer's
/// precision, so float models get their f32 twins pre-sized too) and
/// consumed by [`Scratch::for_spec`]. The flipped-weight fields
/// (`wt_u8`/`wt_f32`) hold only the *depthwise* stale-pack fallback bound
/// (`Cout·Kh·Kw` per reachable depthwise layer — tiny, see
/// `kernels::dwconv`): dense backward packs are owned by the plan's pack
/// cache (`graph::packs`), and the dense conv masked fallback packs into
/// scratch at its dense bound, growing once on first use.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScratchSpec {
    pub col_u8: usize,
    pub col_f32: usize,
    pub acc_i32: usize,
    pub wt_u8: usize,
    pub wt_f32: usize,
    pub zeros_i32: usize,
    pub zeros_f32: usize,
    /// Forward weight-lane span for layers whose weights are stored packed
    /// sub-byte (`quant::subbyte`): the unpacked u8 lanes the GEMM A-pack
    /// consumes. Zero for all-u8 plans, so the default deployment's arena
    /// is unchanged by the packed-weight feature.
    pub wq_u8: usize,
}

/// Reusable scratch buffers for the im2col/GEMM conv path.
///
/// Holds the packed im2col matrix (u8 for the quantized path, f32 for the
/// float path) and the i32 accumulator tile. Buffers only ever grow, so a
/// scratch sized with [`Scratch::for_model`] performs no allocation on the
/// hot path; [`Scratch::new`] starts empty and grows on first use. The
/// arena is plain owned data — each batch worker thread carries its own.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    col_u8: Vec<u8>,
    col_f32: Vec<f32>,
    acc_i32: Vec<i32>,
    /// Flipped-transposed weight packing for the backward-input GEMM —
    /// the **masked fallback only**: dense packs are plan-owned
    /// (`graph::packs`), so these buffers stay empty on dense runs and
    /// grow once on a sparse run's first masked pack.
    wt_u8: Vec<u8>,
    wt_f32: Vec<f32>,
    /// Zero-filled `row_init` vectors for backward GEMMs (read-only; kept
    /// permanently zeroed so borrowing them costs nothing per call).
    zeros_i32: Vec<i32>,
    zeros_f32: Vec<f32>,
    /// Unpacked forward weight lanes for packed sub-byte layers. Separate
    /// from `wt_u8` because a backward step can hold the flipped pack and
    /// unpack forward lanes within the same borrow region.
    wq_u8: Vec<u8>,
}

impl Scratch {
    /// Empty arena; buffers grow on demand.
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Arena pre-sized from a compiled plan's [`ScratchSpec`]: every
    /// buffer is resized to the largest request any op of the plan can
    /// make, so a full training step — uint8, mixed *or* float32 —
    /// performs zero arena growth after construction (asserted by the
    /// arena-capacity tests in `tests/plan_parity.rs`).
    pub fn for_spec(spec: &ScratchSpec) -> Scratch {
        let mut s = Scratch::new();
        s.col_u8.resize(spec.col_u8, 0);
        s.col_f32.resize(spec.col_f32, 0.0);
        s.acc_i32.resize(spec.acc_i32, 0);
        s.wt_u8.resize(spec.wt_u8, 0);
        s.wt_f32.resize(spec.wt_f32, 0.0);
        s.zeros_i32.resize(spec.zeros_i32, 0);
        s.zeros_f32.resize(spec.zeros_f32, 0.0);
        s.wq_u8.resize(spec.wq_u8, 0);
        s
    }

    /// Arena pre-sized for the **uint8 deployment** of `def` (the paper's
    /// main configuration). Delegates to the compiled execution plan's
    /// exact scratch requirements (`graph::plan::ExecPlan::compile`), so
    /// this and [`Scratch::for_spec`] can never drift apart. Kept for
    /// callers that hold a `ModelDef` but no deployed model; production
    /// paths use `NativeModel::make_scratch`, which additionally covers
    /// the float32/mixed configurations.
    pub fn for_model(def: &ModelDef) -> Scratch {
        crate::graph::plan::ExecPlan::compile(def, DnnConfig::Uint8).make_scratch()
    }

    /// Borrow the u8 im2col buffer and the i32 accumulator tile for one
    /// quantized conv call, growing them if needed. Contents are
    /// unspecified — callers fully overwrite both.
    pub fn qconv_bufs(&mut self, col_len: usize, acc_len: usize) -> (&mut [u8], &mut [i32]) {
        if self.col_u8.len() < col_len {
            self.col_u8.resize(col_len, 0);
        }
        if self.acc_i32.len() < acc_len {
            self.acc_i32.resize(acc_len, 0);
        }
        (&mut self.col_u8[..col_len], &mut self.acc_i32[..acc_len])
    }

    /// Borrow the weight-lane buffer, the u8 im2col buffer and the i32
    /// accumulator tile for one quantized conv call on *packed sub-byte*
    /// weights: the lane buffer receives the unpacked u8 weight lanes
    /// before the GEMM consumes them as its A operand. Growing semantics
    /// and contents match [`Scratch::qconv_bufs`].
    pub fn qconv_pa_bufs(
        &mut self,
        wq_len: usize,
        col_len: usize,
        acc_len: usize,
    ) -> (&mut [u8], &mut [u8], &mut [i32]) {
        if self.wq_u8.len() < wq_len {
            self.wq_u8.resize(wq_len, 0);
        }
        if self.col_u8.len() < col_len {
            self.col_u8.resize(col_len, 0);
        }
        if self.acc_i32.len() < acc_len {
            self.acc_i32.resize(acc_len, 0);
        }
        (&mut self.wq_u8[..wq_len], &mut self.col_u8[..col_len], &mut self.acc_i32[..acc_len])
    }

    /// Borrow the weight-lane buffer alongside the backward GEMM buffers
    /// for one packed-weight backward-input call: lane buffer, backward
    /// column matrix, i32 accumulator and zeroed `row_init`. The lane
    /// buffer is distinct from the `wt_u8` flipped-pack store, so callers
    /// that hold a plan-owned flipped pack can still unpack lanes here.
    pub fn qconv_bwd_pa_bufs(
        &mut self,
        wq_len: usize,
        col_len: usize,
        acc_len: usize,
        init_len: usize,
    ) -> (&mut [u8], &mut [u8], &mut [i32], &[i32]) {
        if self.wq_u8.len() < wq_len {
            self.wq_u8.resize(wq_len, 0);
        }
        if self.col_u8.len() < col_len {
            self.col_u8.resize(col_len, 0);
        }
        if self.acc_i32.len() < acc_len {
            self.acc_i32.resize(acc_len, 0);
        }
        if self.zeros_i32.len() < init_len {
            self.zeros_i32.resize(init_len, 0);
        }
        (
            &mut self.wq_u8[..wq_len],
            &mut self.col_u8[..col_len],
            &mut self.acc_i32[..acc_len],
            &self.zeros_i32[..init_len],
        )
    }

    /// Borrow the f32 im2col buffer for one float conv call.
    pub fn fconv_col(&mut self, len: usize) -> &mut [f32] {
        if self.col_f32.len() < len {
            self.col_f32.resize(len, 0.0);
        }
        &mut self.col_f32[..len]
    }

    /// Borrow the buffers of one quantized backward GEMM call: the flipped
    /// weight packing, the backward column matrix, the i32 accumulator and
    /// a zeroed `row_init` slice. Contents of the first three are
    /// unspecified — callers fully overwrite them; the init slice is
    /// permanently zero.
    pub fn qconv_bwd_bufs(
        &mut self,
        wt_len: usize,
        col_len: usize,
        acc_len: usize,
        init_len: usize,
    ) -> (&mut [u8], &mut [u8], &mut [i32], &[i32]) {
        if self.wt_u8.len() < wt_len {
            self.wt_u8.resize(wt_len, 0);
        }
        if self.col_u8.len() < col_len {
            self.col_u8.resize(col_len, 0);
        }
        if self.acc_i32.len() < acc_len {
            self.acc_i32.resize(acc_len, 0);
        }
        if self.zeros_i32.len() < init_len {
            self.zeros_i32.resize(init_len, 0);
        }
        (
            &mut self.wt_u8[..wt_len],
            &mut self.col_u8[..col_len],
            &mut self.acc_i32[..acc_len],
            &self.zeros_i32[..init_len],
        )
    }

    /// Float twin of [`Scratch::qconv_bwd_bufs`]: flipped weight packing,
    /// backward column matrix and a zeroed f32 `row_init` slice (the f32
    /// GEMM writes straight into the output tensor, so no accumulator).
    pub fn fconv_bwd_bufs(
        &mut self,
        wt_len: usize,
        col_len: usize,
        init_len: usize,
    ) -> (&mut [f32], &mut [f32], &[f32]) {
        if self.wt_f32.len() < wt_len {
            self.wt_f32.resize(wt_len, 0.0);
        }
        if self.col_f32.len() < col_len {
            self.col_f32.resize(col_len, 0.0);
        }
        if self.zeros_f32.len() < init_len {
            self.zeros_f32.resize(init_len, 0.0);
        }
        (&mut self.wt_f32[..wt_len], &mut self.col_f32[..col_len], &self.zeros_f32[..init_len])
    }

    /// Borrow the 180°-flipped depthwise weight buffer for one
    /// backward-input call that could not use the plan-owned pack (the
    /// stale-cache bypass of `kernels::dwconv`). Reuses the `wt_u8`
    /// backing store — both users are transient within a single kernel
    /// call. Contents are unspecified; callers fully overwrite.
    pub fn dw_wt_u8(&mut self, len: usize) -> &mut [u8] {
        if self.wt_u8.len() < len {
            self.wt_u8.resize(len, 0);
        }
        &mut self.wt_u8[..len]
    }

    /// f32 twin of [`Scratch::dw_wt_u8`].
    pub fn dw_wt_f32(&mut self, len: usize) -> &mut [f32] {
        if self.wt_f32.len() < len {
            self.wt_f32.resize(len, 0.0);
        }
        &mut self.wt_f32[..len]
    }

    /// Currently reserved bytes across all buffers (diagnostics / memory
    /// accounting).
    pub fn reserved_bytes(&self) -> usize {
        self.col_u8.len()
            + self.wt_u8.len()
            + self.wq_u8.len()
            + (self.col_f32.len() + self.wt_f32.len()) * 4
            + (self.acc_i32.len() + self.zeros_i32.len() + self.zeros_f32.len()) * 4
    }
}

/// Fixed Flash overhead of the runtime image (scheduler, kernels, CLI).
pub const RUNTIME_FLASH_BYTES: usize = 48 * 1024;

/// One tensor to place in the arena.
#[derive(Clone, Debug)]
pub struct ArenaItem {
    pub name: String,
    pub bytes: usize,
    /// First timestep (inclusive) the tensor is live.
    pub birth: usize,
    /// Last timestep (inclusive).
    pub death: usize,
}

/// Result of arena placement.
#[derive(Clone, Debug)]
pub struct ArenaPlan {
    pub items: Vec<(ArenaItem, usize)>, // (item, offset)
    pub total_bytes: usize,
}

/// Greedy best-fit placement: size-descending, first offset where the item
/// fits without overlapping any already-placed, lifetime-overlapping item.
pub fn allocate_arena(mut items: Vec<ArenaItem>) -> ArenaPlan {
    items.sort_by(|a, b| b.bytes.cmp(&a.bytes).then(a.birth.cmp(&b.birth)));
    let mut placed: Vec<(ArenaItem, usize)> = Vec::with_capacity(items.len());
    let mut total = 0usize;
    for it in items {
        // collect intervals of already-placed, time-overlapping items
        let mut blocked: Vec<(usize, usize)> = placed
            .iter()
            .filter(|(p, _)| !(p.death < it.birth || p.birth > it.death))
            .map(|(p, off)| (*off, *off + p.bytes))
            .collect();
        blocked.sort_unstable();
        // first gap large enough
        let mut offset = 0usize;
        for (lo, hi) in blocked {
            if offset + it.bytes <= lo {
                break;
            }
            offset = offset.max(hi);
        }
        total = total.max(offset + it.bytes);
        placed.push((it, offset));
    }
    ArenaPlan { items: placed, total_bytes: total }
}

/// Round `n` up to the next multiple of `a` (`a > 0`).
///
/// Used by backends whose storage is word-granular (the GPU arena binds one
/// `array<u32>` buffer): padding every [`ArenaItem::bytes`] to a multiple of
/// the word size before [`allocate_arena`] keeps every placed offset
/// word-aligned — the greedy placement only ever produces offsets that are
/// sums of already-placed item ends, so aligned sizes imply aligned offsets.
pub fn align_up(n: usize, a: usize) -> usize {
    assert!(a > 0, "alignment must be positive");
    n.div_ceil(a) * a
}

/// The three-segment memory report (Figs. 4c/4d).
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryReport {
    /// Feature-map arena bytes (activations + error tensors + argmaxes),
    /// from the analytic per-layer timeline.
    pub feature_ram: usize,
    /// Trainable weights + gradient buffers + optimizer state bytes.
    pub weight_ram: usize,
    /// Frozen weights + runtime image bytes.
    pub flash: usize,
    /// Peak feature-arena bytes of the *compiled execution plan*
    /// (`graph::plan`): the liveness of what the planned ops actually
    /// allocate — zero-copy `Flatten` aliasing included, transient
    /// precision-boundary staging buffers included — lowered onto
    /// [`allocate_arena`]. This is the number the harness reports so
    /// Fig. 5-style memory claims are reproducible from one run.
    pub planned_peak_bytes: usize,
}

impl MemoryReport {
    pub fn total_ram(&self) -> usize {
        self.feature_ram + self.weight_ram
    }
}

fn act_bytes(shape: &[usize], prec: Precision) -> usize {
    let n: usize = shape.iter().product();
    match prec {
        Precision::Uint8 => n,
        Precision::Float32 => n * 4,
    }
}

/// Plan memory for a deployment. `training=false` gives the inference-only
/// plan (the baseline the training overhead is measured against).
pub fn plan(def: &ModelDef, cfg: DnnConfig, training: bool) -> MemoryReport {
    let n = def.layers.len();
    let prec = def.precisions(cfg);
    let shapes = def.shapes();
    let stop = if training { def.first_trainable().unwrap_or(n) } else { n };

    // --- feature arena -------------------------------------------------
    // Timeline: fwd steps 0..n, bwd steps for layer i at time 2n-1-i.
    let bwd_t = |i: usize| 2 * n - 1 - i;
    let mut items: Vec<ArenaItem> = Vec::new();

    // input tensor: live through fwd step 0; if layer 0 is trainable its
    // input is needed at layer 0's backward step.
    let in_prec = prec[0];
    let input_death = if training && def.layers[0].trainable { bwd_t(0) } else { 0 };
    items.push(ArenaItem {
        name: "input".into(),
        bytes: act_bytes(&def.input_shape, in_prec),
        birth: 0,
        death: input_death,
    });

    for i in 0..n {
        // activation of layer i: born at fwd step i, consumed at fwd i+1;
        // training extends it if (a) layer i+1 is trainable (bwd_weight
        // needs its input), or (b) layer i itself needs its output for the
        // backward pass (ReLU mask / pool routing) and the error reaches it.
        let mut death = if i + 1 < n { i + 1 } else { i };
        if training {
            if i + 1 < n && def.layers[i + 1].trainable {
                death = death.max(bwd_t(i + 1));
            }
            let err_reaches = i >= stop;
            let needs_own_output = matches!(
                def.layers[i].kind,
                LayerKind::Conv { relu: true, .. } | LayerKind::Linear { relu: true, .. }
            );
            if err_reaches && needs_own_output {
                death = death.max(bwd_t(i));
            }
            // final activation feeds the loss at the start of backward
            if i == n - 1 {
                death = death.max(bwd_t(n - 1));
            }
        }
        items.push(ArenaItem {
            name: format!("act{i}"),
            bytes: act_bytes(&shapes[i], prec[i]),
            birth: i,
            death,
        });

        if training {
            // pool argmax buffers (u32 per output) live fwd(i)..bwd(i)
            if matches!(def.layers[i].kind, LayerKind::MaxPool { .. }) && i >= stop {
                let n_out: usize = shapes[i].iter().product();
                items.push(ArenaItem {
                    name: format!("argmax{i}"),
                    bytes: n_out * 4,
                    birth: i,
                    death: bwd_t(i),
                });
            }
            // error tensor w.r.t. output of layer i: born at bwd(i)
            // (produced by layer i+1's backward or the loss), consumed at
            // bwd(i) by layer i.
            if i >= stop {
                items.push(ArenaItem {
                    name: format!("err{i}"),
                    bytes: act_bytes(&shapes[i], prec[i]),
                    birth: bwd_t(i).saturating_sub(1),
                    death: bwd_t(i),
                });
            }
        }
    }
    let arena = allocate_arena(items);

    // --- weights: RAM for trainable, Flash for frozen -------------------
    let mut weight_ram = 0usize;
    let mut flash = RUNTIME_FLASH_BYTES;
    for (i, l) in def.layers.iter().enumerate() {
        let (n_w, n_b) = match &l.kind {
            LayerKind::Conv { geom, .. } => (geom.weights(), geom.cout),
            LayerKind::Linear { n_in, n_out, .. } => (n_in * n_out, *n_out),
            _ => continue,
        };
        let w_bytes = match prec[i] {
            Precision::Uint8 => n_w + n_b * 4, // u8 weights + f32 bias
            Precision::Float32 => (n_w + n_b) * 4,
        };
        if training && l.trainable {
            weight_ram += w_bytes;
            // gradient accumulation buffers (f32 weight + bias grads) and
            // per-structure running stats (§III-A)
            weight_ram += (n_w + n_b) * 4;
            weight_ram += n_b * 17; // Welford n/mean/m2 + touched flag
        } else {
            flash += w_bytes;
        }
    }

    let planned = crate::graph::plan::planned_arena(def, cfg, training);
    MemoryReport {
        feature_ram: arena.total_bytes,
        weight_ram,
        flash,
        planned_peak_bytes: planned.total_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::Prop;

    #[test]
    fn arena_reuses_disjoint_lifetimes() {
        let items = vec![
            ArenaItem { name: "a".into(), bytes: 100, birth: 0, death: 1 },
            ArenaItem { name: "b".into(), bytes: 100, birth: 2, death: 3 },
        ];
        let plan = allocate_arena(items);
        assert_eq!(plan.total_bytes, 100, "disjoint tensors must share");
    }

    #[test]
    fn arena_never_overlaps_live_tensors() {
        let items = vec![
            ArenaItem { name: "a".into(), bytes: 100, birth: 0, death: 2 },
            ArenaItem { name: "b".into(), bytes: 50, birth: 1, death: 3 },
            ArenaItem { name: "c".into(), bytes: 70, birth: 2, death: 2 },
        ];
        let plan = allocate_arena(items);
        assert_eq!(plan.total_bytes, 220);
    }

    #[test]
    fn prop_arena_no_live_overlap() {
        Prop::new(64).check(
            |r: &mut Pcg32| {
                let n = 2 + r.below(12) as usize;
                (0..n)
                    .map(|i| {
                        let birth = r.below(10) as usize;
                        ArenaItem {
                            name: format!("t{i}"),
                            bytes: 1 + r.below(256) as usize,
                            birth,
                            death: birth + r.below(6) as usize,
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |items| {
                if items.len() > 2 {
                    vec![items[..items.len() - 1].to_vec()]
                } else {
                    vec![]
                }
            },
            |items| {
                let plan = allocate_arena(items.clone());
                for (i, (a, ao)) in plan.items.iter().enumerate() {
                    for (b, bo) in plan.items.iter().skip(i + 1) {
                        let time_overlap = !(a.death < b.birth || a.birth > b.death);
                        let mem_overlap = ao < &(bo + b.bytes) && bo < &(ao + a.bytes);
                        if time_overlap && mem_overlap {
                            return Err(format!("{} and {} overlap", a.name, b.name));
                        }
                    }
                }
                if plan.total_bytes > items.iter().map(|i| i.bytes).sum::<usize>() {
                    return Err("arena larger than sum of tensors".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn training_needs_more_feature_ram_than_inference() {
        let m = models::mnist_cnn(&[1, 28, 28], 10);
        let inf = plan(&m, DnnConfig::Uint8, false);
        let tr = plan(&m, DnnConfig::Uint8, true);
        assert!(tr.feature_ram > inf.feature_ram, "{} vs {}", tr.feature_ram, inf.feature_ram);
        assert!(tr.weight_ram > 0 && inf.weight_ram == 0);
    }

    #[test]
    fn float_config_needs_more_ram_than_uint8() {
        let m = models::mnist_cnn(&[1, 28, 28], 10);
        let q = plan(&m, DnnConfig::Uint8, true);
        let f = plan(&m, DnnConfig::Float32, true);
        assert!(f.feature_ram > 2 * q.feature_ram);
        assert!(f.total_ram() > q.total_ram());
        // mixed sits in between
        let mx = plan(&m, DnnConfig::Mixed, true);
        assert!(mx.total_ram() > q.total_ram() && mx.total_ram() < f.total_ram());
    }

    #[test]
    fn transfer_learning_puts_frozen_weights_in_flash() {
        let mut m = models::mbednet(&[3, 32, 32], 10);
        m.set_trainable_tail(2);
        let tl = plan(&m, DnnConfig::Uint8, true);
        m.set_all_trainable();
        let full = plan(&m, DnnConfig::Uint8, true);
        assert!(tl.flash > full.flash, "frozen weights must live in flash");
        assert!(tl.weight_ram < full.weight_ram);
    }

    #[test]
    fn mnist_cnn_uint8_fits_all_tab2_mcus() {
        // §IV-D deploys the uint8 full-training configuration on all three
        // MCUs — our stand-in must satisfy the same constraint.
        let m = models::mnist_cnn(&[1, 28, 28], 10);
        let rep = plan(&m, DnnConfig::Uint8, true);
        for d in crate::device::all_devices() {
            assert!(
                d.fits(rep.total_ram(), rep.flash),
                "{}: ram={} flash={}",
                d.name,
                rep.total_ram(),
                rep.flash
            );
        }
    }

    #[test]
    fn scratch_for_model_presizes_largest_conv() {
        let m = models::mnist_cnn(&[1, 12, 12], 4);
        let s = Scratch::for_model(&m);
        assert!(s.reserved_bytes() > 0);
        // serving a smaller conv must not grow beyond the presize
        let mut s2 = s.clone();
        let before = s2.reserved_bytes();
        let (col, acc) = s2.qconv_bufs(9, 16);
        assert_eq!(col.len(), 9);
        assert_eq!(acc.len(), 16);
        assert_eq!(s2.reserved_bytes(), before);
    }

    #[test]
    fn scratch_backward_bufs_grow_and_init_stays_zero() {
        let mut s = Scratch::new();
        {
            let (wt, col, acc, init) = s.qconv_bwd_bufs(10, 20, 30, 4);
            assert_eq!((wt.len(), col.len(), acc.len(), init.len()), (10, 20, 30, 4));
            assert!(init.iter().all(|&v| v == 0));
        }
        {
            let (wt, col, init) = s.fconv_bwd_bufs(5, 6, 3);
            assert_eq!((wt.len(), col.len(), init.len()), (5, 6, 3));
            assert!(init.iter().all(|&v| v == 0.0));
        }
        // for_model pre-reserves the backward col/acc/init buffers of the
        // model's own convs (the flipped-weight pack is plan-owned, so a
        // dense backward call requests wt_len == 0): serving a smaller
        // backward call must not grow the arena.
        let m = models::mnist_cnn(&[1, 12, 12], 4);
        let mut s2 = Scratch::for_model(&m);
        let before = s2.reserved_bytes();
        let _ = s2.qconv_bwd_bufs(0, 9, 16, 1);
        assert_eq!(s2.reserved_bytes(), before);
    }

    #[test]
    fn scratch_for_spec_presizes_exactly() {
        let spec = ScratchSpec {
            col_u8: 10,
            col_f32: 4,
            acc_i32: 6,
            wt_u8: 3,
            wt_f32: 2,
            zeros_i32: 5,
            zeros_f32: 1,
            wq_u8: 7,
        };
        let s = Scratch::for_spec(&spec);
        assert_eq!(s.reserved_bytes(), 10 + 3 + 7 + (4 + 2) * 4 + (6 + 5 + 1) * 4);
        // serving requests within the spec must not grow the arena
        let mut s2 = s.clone();
        let before = s2.reserved_bytes();
        let _ = s2.qconv_bufs(10, 6);
        let _ = s2.qconv_bwd_bufs(3, 10, 6, 5);
        let _ = s2.fconv_bwd_bufs(2, 4, 1);
        let _ = s2.qconv_pa_bufs(7, 10, 6);
        let _ = s2.qconv_bwd_pa_bufs(7, 10, 6, 5);
        assert_eq!(s2.reserved_bytes(), before);
    }

    #[test]
    fn memory_report_carries_planned_peak() {
        let m = models::mnist_cnn(&[1, 28, 28], 10);
        let tr = plan(&m, DnnConfig::Uint8, true);
        let inf = plan(&m, DnnConfig::Uint8, false);
        assert!(tr.planned_peak_bytes > 0);
        assert!(tr.planned_peak_bytes > inf.planned_peak_bytes);
    }

    #[test]
    fn scratch_grows_on_demand() {
        let mut s = Scratch::new();
        assert_eq!(s.reserved_bytes(), 0);
        {
            let (col, acc) = s.qconv_bufs(100, 50);
            assert_eq!((col.len(), acc.len()), (100, 50));
        }
        let f = s.fconv_col(70);
        assert_eq!(f.len(), 70);
        assert!(s.reserved_bytes() >= 100 + 50 * 4 + 70 * 4);
    }

    #[test]
    fn mcunet_heavier_than_mbednet_for_training() {
        // Fig. 9: MbedNet needs less training memory than MCUNet.
        let mb = models::mbednet(&[3, 32, 32], 10);
        let mc = models::mcunet5fps(&[3, 32, 32], 10);
        let rb = plan(&mb, DnnConfig::Uint8, true);
        let rc = plan(&mc, DnnConfig::Uint8, true);
        assert!(rc.total_ram() > rb.total_ram(), "{} vs {}", rc.total_ram(), rb.total_ram());
    }

    #[test]
    fn align_up_rounds_and_preserves_multiples() {
        assert_eq!(align_up(0, 4), 0);
        assert_eq!(align_up(1, 4), 4);
        assert_eq!(align_up(4, 4), 4);
        assert_eq!(align_up(5, 4), 8);
        assert_eq!(align_up(17, 1), 17);
    }

    #[test]
    fn word_aligned_items_place_at_word_aligned_offsets() {
        // The property the GPU backend relies on: padding every item's size
        // to a word multiple makes every greedy placement offset a word
        // multiple too (offsets are sums of already-placed item ends).
        let mut rng = Pcg32::new(0xA11C, 0);
        let items: Vec<ArenaItem> = (0..24)
            .map(|i| {
                let birth = (rng.next_u32() % 10) as usize;
                ArenaItem {
                    name: format!("it{i}"),
                    bytes: align_up(1 + (rng.next_u32() % 900) as usize, 4),
                    birth,
                    death: birth + (rng.next_u32() % 5) as usize,
                }
            })
            .collect();
        let plan = allocate_arena(items);
        for (it, off) in &plan.items {
            assert_eq!(off % 4, 0, "{} placed at unaligned offset {off}", it.name);
        }
        assert_eq!(plan.total_bytes % 4, 0);
    }
}
