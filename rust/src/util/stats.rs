//! Small statistics helpers shared by the optimizer (running moments for
//! gradient standardization, Eq. 8), the observers (EMA min/max), the device
//! model, and the bench harness (mean/std over repeated runs).

/// Welford running mean/variance — numerically stable, O(1) memory.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 while fewer than 2 samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponential moving average of a scalar, used by min/max observers.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    value: f32,
    alpha: f32,
    primed: bool,
}

impl Ema {
    pub fn new(alpha: f32) -> Self {
        Ema { value: 0.0, alpha, primed: false }
    }

    pub fn push(&mut self, x: f32) {
        if self.primed {
            self.value += self.alpha * (x - self.value);
        } else {
            self.value = x;
            self.primed = true;
        }
    }

    pub fn get(&self) -> f32 {
        self.value
    }

    pub fn primed(&self) -> bool {
        self.primed
    }

    /// Force a value (used when restoring observer state).
    pub fn set(&mut self, x: f32) {
        self.value = x;
        self.primed = true;
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population standard deviation of a slice.
pub fn std(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m) * (x as f64 - m)).sum::<f64>() / xs.len() as f64;
    var.sqrt() as f32
}

/// (min, max) over a slice; (0, 0) for empty input.
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

/// L1 norm.
pub fn l1(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x.abs() as f64).sum::<f64>() as f32
}

/// Index of the maximum value (first occurrence). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest values, descending (first occurrence wins ties).
/// O(n log n); n is the number of *structures* per layer (small).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(core::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k.min(xs.len()));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-9);
        let m = 4.0;
        let var: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 5.0;
        assert!((w.variance() - var).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.push(0.0);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn top_k_ordering_and_ties() {
        let xs = [1.0, 5.0, 3.0, 5.0, 2.0];
        assert_eq!(top_k_indices(&xs, 3), vec![1, 3, 2]);
        assert_eq!(top_k_indices(&xs, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&xs, 99).len(), 5);
    }

    #[test]
    fn min_max_basic() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
        assert_eq!(min_max(&[]), (0.0, 0.0));
    }

    #[test]
    fn argmax_first_occurrence() {
        assert_eq!(argmax(&[1.0, 7.0, 7.0, 2.0]), 1);
    }
}
