//! Minimal property-based testing driver.
//!
//! The offline vendor set does not include `proptest`, so we provide a small
//! equivalent: run a property over many PRNG-generated cases; on failure,
//! greedily shrink the failing case by halving numeric fields and retrying.
//! Used by the quant / kernels / sparse / memplan test suites to sweep shapes
//! and quantization parameters.

use crate::util::prng::Pcg32;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: 0xC0FFEE }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `prop` over `cases` generated inputs. `gen` draws a case from the
    /// PRNG; `prop` returns Err(description) on violation. `shrink` proposes
    /// smaller candidates for a failing case (may be empty).
    pub fn check<T: Clone + std::fmt::Debug>(
        &self,
        gen: impl Fn(&mut Pcg32) -> T,
        shrink: impl Fn(&T) -> Vec<T>,
        prop: impl Fn(&T) -> Result<(), String>,
    ) {
        let mut rng = Pcg32::new(self.seed, 77);
        for case_no in 0..self.cases {
            let case = gen(&mut rng);
            if let Err(msg) = prop(&case) {
                // Greedy shrink: repeatedly take the first shrunk candidate
                // that still fails, up to a bounded number of rounds.
                let mut smallest = case.clone();
                let mut smallest_msg = msg;
                'outer: for _ in 0..200 {
                    for cand in shrink(&smallest) {
                        if let Err(m) = prop(&cand) {
                            smallest = cand;
                            smallest_msg = m;
                            continue 'outer;
                        }
                    }
                    break;
                }
                panic!(
                    "property failed (case {case_no}/{}):\n  input: {smallest:?}\n  error: {smallest_msg}",
                    self.cases
                );
            }
        }
    }
}

/// Shrink helper: candidates for a usize dimension (halve toward `min`).
pub fn shrink_dim(v: usize, min: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if v > min {
        out.push(min);
        let half = (v + min) / 2;
        if half != v && half != min {
            out.push(half);
        }
        if v - 1 != min {
            out.push(v - 1);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        Prop::new(50).check(
            |r| r.below(1000) as usize,
            |v| shrink_dim(*v, 0),
            |v| if *v < 1000 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_shrinks() {
        Prop::new(50).check(
            |r| 10 + r.below(100) as usize,
            |v| shrink_dim(*v, 0),
            |v| if *v < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn shrink_dim_monotone() {
        for &c in &shrink_dim(64, 1) {
            assert!(c < 64 && c >= 1);
        }
        assert!(shrink_dim(1, 1).is_empty());
    }
}
