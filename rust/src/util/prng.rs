//! Deterministic PRNG (PCG-XSH-RR 64/32) used everywhere randomness is
//! needed: synthetic data generation, weight init, shuffling, property tests.
//!
//! We implement our own generator instead of pulling `rand` because the
//! offline vendor set does not ship it, and because experiment
//! reproducibility requires a stable, documented stream anyway.

/// PCG-XSH-RR 64/32 — O'Neill 2014. 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next 32 uniformly distributed bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits -> exactly representable, unbiased.
        (self.next_u32() >> 8) as f32 * (1.0 / 16777216.0)
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal sample (Box–Muller; one value per call, simple and
    /// deterministic rather than maximally fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 <= 1e-9 {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * core::f32::consts::PI * u2).cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Fill a slice with N(0, std) values.
    pub fn fill_normal(&mut self, xs: &mut [f32], std: f32) {
        for x in xs.iter_mut() {
            *x = self.normal() * std;
        }
    }

    /// Split off an independent child generator (for parallel workers).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
