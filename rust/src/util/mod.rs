//! Shared utilities: PRNG, statistics, JSON, CLI parsing, a property-test
//! driver and the bench harness. All hand-rolled — the offline build
//! environment only ships the vendored crate set (see DESIGN.md §7).

pub mod argparse;
pub mod bench;
pub mod error;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
