//! Tiny CLI argument parser (the vendor set does not include `clap`).
//!
//! Supports: `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. The binary defines its options declaratively so `--help`
//! output stays accurate.

use std::collections::BTreeMap;

/// Declarative option description used for `--help`.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw tokens. `flag_names` lists options that take no value.
    pub fn parse(tokens: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.kv.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    a.flags.push(stripped.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    a.kv.insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    // trailing --key with no value: treat as flag
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Render a help string from option specs.
pub fn render_help(cmd: &str, about: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\nOptions:\n");
    for o in opts {
        let head = if o.is_flag {
            format!("  --{}", o.name)
        } else {
            format!("  --{} <v>", o.name)
        };
        let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("{head:<28}{}{def}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&toks("--epochs 20 --quick --lr=0.001 input.bin"), &["quick"]).unwrap();
        assert_eq!(a.get("epochs"), Some("20"));
        assert_eq!(a.f32_or("lr", 0.0), 0.001);
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&toks(""), &[]).unwrap();
        assert_eq!(a.usize_or("epochs", 7), 7);
        assert!(!a.flag("quick"));
    }

    #[test]
    fn trailing_key_becomes_flag() {
        let a = Args::parse(&toks("--verbose"), &[]).unwrap();
        assert!(a.flag("verbose"));
    }

    #[test]
    fn double_dash_value_not_consumed() {
        let a = Args::parse(&toks("--a --b 3"), &[]).unwrap();
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("3"));
    }
}
