//! Minimal JSON reader/writer.
//!
//! The offline vendor set ships neither `serde` nor `serde_json`, so we carry
//! a small, well-tested JSON implementation of our own. It is used for two
//! things only: (a) parsing the artifact manifest emitted by
//! `python/compile/aot.py`, and (b) writing bench results under `results/`.
//! It supports the full JSON grammar minus `\u` surrogate pairs outside the
//! BMP (the manifest is ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Numbers are kept as f64 (the manifest only contains
/// shapes/ids that fit exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns Null for missing keys to allow chaining.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element access.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: &'static str,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { pos: self.pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8, msg: &'static str) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(msg))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"', "expected '\"'")?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code: u32 = 0;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad \\u digit"))?;
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("non-BMP \\u"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = core::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = core::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[', "expected '['")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{', "expected '{'")?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':', "expected ':'")?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi\n","c":true,"d":null,"e":{"x":0}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.5));
        assert_eq!(v.get("b").as_str(), Some("hi\n"));
        assert_eq!(v.get("c").as_bool(), Some(true));
        assert_eq!(v.get("d"), &Json::Null);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(v.at(1).at(1).at(0).as_f64(), Some(4.0));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\té""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\u{e9}"));
        let v2 = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v2.as_str(), Some("caf\u{e9}"));
    }

    #[test]
    fn missing_key_chains_to_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope").get("deeper").at(3), &Json::Null);
    }
}
