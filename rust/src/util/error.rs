//! Minimal error plumbing for fallible subsystems (the PJRT runtime, the
//! artifact manifests). The offline vendor set ships neither `anyhow` nor
//! `thiserror`, so this module carries the tiny subset we use: a string-y
//! error type with a context chain, a [`Context`] extension trait for
//! `Result`/`Option`, and the [`crate::bail!`]/[`crate::ensure!`] macros.

use std::fmt;

/// A boxed-string error with an outermost-first context chain, printed as
/// `context: deeper context: root cause` (what `anyhow`'s `{:#}` shows).
pub struct Error {
    msg: String,
    /// Contexts, innermost first (pushed as the error propagates outward).
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), chain: Vec::new() }
    }

    /// Attach one more layer of context.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// The root-cause message, without contexts.
    pub fn root_cause(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.chain.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Early-return with a formatted [`Error`] unless the condition holds
/// (mirrors `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(Error::msg("root cause"))
    }

    #[test]
    fn context_chain_prints_outermost_first() {
        let e = fails().context("loading artifact").unwrap_err().context("running bench");
        assert_eq!(e.to_string(), "running bench: loading artifact: root cause");
        assert_eq!(e.root_cause(), "root cause");
    }

    #[test]
    fn option_context_converts_none() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, String> = Ok(1);
        let v = ok.with_context(|| unreachable!("must not evaluate on Ok"));
        assert_eq!(v.unwrap(), 1);
    }

    #[test]
    fn bail_and_ensure_macros() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too large: {x}");
            if x == 0 {
                crate::bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed");
        assert_eq!(f(11).unwrap_err().to_string(), "x too large: 11");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/path")?)
        }
        assert!(read().is_err());
    }
}
