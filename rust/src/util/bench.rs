//! Bench harness (the vendor set has no `criterion`).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary built on
//! this module: it runs the workload, prints paper-style tables to stdout,
//! and writes machine-readable JSON rows under `results/`. Timing helpers
//! give mean/std over repetitions with a warm-up phase.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// Time `f` with `warmup` unmeasured runs and `reps` measured runs.
/// Returns (mean_seconds, std_seconds).
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() as f32);
    }
    (stats::mean(&samples) as f64, stats::std(&samples) as f64)
}

/// A printable results table with fixed-width columns.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Accumulates JSON result rows and writes them to `results/<name>.json`.
pub struct ResultSink {
    name: String,
    rows: Vec<Json>,
}

impl ResultSink {
    pub fn new(name: &str) -> Self {
        ResultSink { name: name.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// The rows accumulated so far (e.g. for embedding into a secondary
    /// machine-readable artifact such as `BENCH_kernels.json`).
    pub fn rows(&self) -> &[Json] {
        &self.rows
    }

    /// Write all accumulated rows. Creates `results/` if needed.
    pub fn flush(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        std::fs::write(&path, Json::Arr(self.rows.clone()).to_string())?;
        Ok(path)
    }
}

/// Read a bench-scaling knob from the environment (e.g. TT_EPOCHS, TT_RUNS)
/// so recorded runs can trade fidelity for wall-clock.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Format seconds as an adaptive human unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive_mean() {
        let (mean, _) = time_it(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= 0.0);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(0.002).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }
}
