//! Bench harness (the vendor set has no `criterion`).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary built on
//! this module: it runs the workload, prints paper-style tables to stdout,
//! and writes machine-readable JSON rows under `results/`. Timing helpers
//! give mean/std over repetitions with a warm-up phase.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// Time `f` with `warmup` unmeasured runs and `reps` measured runs.
/// Returns (mean_seconds, std_seconds).
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() as f32);
    }
    (stats::mean(&samples) as f64, stats::std(&samples) as f64)
}

/// A printable results table with fixed-width columns.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Accumulates JSON result rows and writes them to `results/<name>.json`.
pub struct ResultSink {
    name: String,
    rows: Vec<Json>,
}

impl ResultSink {
    pub fn new(name: &str) -> Self {
        ResultSink { name: name.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// The rows accumulated so far (e.g. for embedding into a secondary
    /// machine-readable artifact such as `BENCH_kernels.json`).
    pub fn rows(&self) -> &[Json] {
        &self.rows
    }

    /// Write all accumulated rows. Creates `results/` if needed.
    pub fn flush(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        std::fs::write(&path, Json::Arr(self.rows.clone()).to_string())?;
        Ok(path)
    }
}

/// Speedup of `new` relative to `base` (`base / new`), guarded against
/// degenerate timings: returns `None` unless both inputs are finite and
/// strictly positive. A zero or sub-resolution denominator would emit an
/// infinite (or NaN) ratio that poisons every downstream aggregate, so
/// benches drop the row instead of writing it.
pub fn safe_speedup(base: f64, new: f64) -> Option<f64> {
    (base.is_finite() && new.is_finite() && base > 0.0 && new > 0.0).then(|| base / new)
}

/// Geometric mean of a set of ratios, guarded the same way as
/// [`safe_speedup`]: `None` if the slice is empty or any element is
/// non-finite or ≤ 0 (one bad element would silently drag the whole
/// aggregate to NaN/0/∞ through the log-sum).
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|x| !x.is_finite() || *x <= 0.0) {
        return None;
    }
    Some((xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp())
}

/// Schema check for `perf_kernels` JSON rows, shared by the bench itself
/// (which asserts it before writing `BENCH_kernels.json`) and the CI
/// perf-regression gate (`bench_gate`, which refuses malformed input):
/// every row must be an object carrying a non-empty `"kernel"` string and
/// at least one numeric metric, and every number anywhere in the row must
/// be finite — a NaN or infinity would silently poison the gate's
/// baseline comparisons. Two field families get range checks on top:
/// `*seconds*` must be ≥ 0 and `*speedup*` must be > 0, since a negative
/// time or non-positive ratio can only come from a broken measurement and
/// would invert the gate's regression comparisons.
pub fn check_perf_rows(rows: &[Json]) -> Result<(), String> {
    fn all_finite(v: &Json, path: &str) -> Result<(), String> {
        match v {
            Json::Num(n) if !n.is_finite() => Err(format!("non-finite number at {path}: {n}")),
            Json::Arr(a) => {
                for (i, item) in a.iter().enumerate() {
                    all_finite(item, &format!("{path}[{i}]"))?;
                }
                Ok(())
            }
            Json::Obj(o) => {
                for (k, item) in o {
                    all_finite(item, &format!("{path}.{k}"))?;
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }
    for (i, row) in rows.iter().enumerate() {
        let obj = row.as_obj().ok_or_else(|| format!("row {i} is not an object"))?;
        match row.get("kernel").as_str() {
            Some(k) if !k.is_empty() => {}
            _ => return Err(format!("row {i} is missing a non-empty \"kernel\" string")),
        }
        if !obj.values().any(|v| matches!(v, Json::Num(_))) {
            return Err(format!("row {i} carries no numeric metric"));
        }
        all_finite(row, &format!("row {i}"))?;
        for (name, v) in obj {
            let Some(n) = v.as_f64() else { continue };
            if name.contains("seconds") && n < 0.0 {
                return Err(format!("row {i}: negative duration {name} = {n}"));
            }
            if name.contains("speedup") && n <= 0.0 {
                return Err(format!("row {i}: non-positive ratio {name} = {n}"));
            }
        }
    }
    Ok(())
}

/// Read a bench-scaling knob from the environment (e.g. TT_EPOCHS, TT_RUNS)
/// so recorded runs can trade fidelity for wall-clock.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Format seconds as an adaptive human unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive_mean() {
        let (mean, _) = time_it(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= 0.0);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn perf_row_schema_accepts_well_formed_rows() {
        // Representative of what perf_kernels actually emits: flat metric
        // rows and rows with nested structure.
        let rows = vec![
            Json::obj(vec![
                ("kernel", Json::str("qdwconv2d_fwd")),
                ("seconds", Json::Num(1.5e-4)),
                ("gmacs", Json::Num(3.2)),
            ]),
            Json::obj(vec![
                ("kernel", Json::str("qdwconv2d_bwd_sparsity")),
                ("kept_fraction", Json::Num(0.5)),
                ("bwd_input_blocked_speedup", Json::Num(2.0)),
                ("shape", Json::str("32x32x32")),
            ]),
        ];
        assert!(check_perf_rows(&rows).is_ok());
        assert!(check_perf_rows(&[]).is_ok());
    }

    #[test]
    fn perf_row_schema_rejects_malformed_rows() {
        // NaN metric
        let nan = vec![Json::obj(vec![
            ("kernel", Json::str("x")),
            ("seconds", Json::Num(f64::NAN)),
        ])];
        assert!(check_perf_rows(&nan).unwrap_err().contains("non-finite"));
        // infinity nested inside an array
        let inf = vec![Json::obj(vec![
            ("kernel", Json::str("x")),
            ("n", Json::Num(1.0)),
            ("samples", Json::Arr(vec![Json::Num(f64::INFINITY)])),
        ])];
        assert!(check_perf_rows(&inf).unwrap_err().contains("non-finite"));
        // missing / empty kernel name
        let unnamed = vec![Json::obj(vec![("seconds", Json::Num(1.0))])];
        assert!(check_perf_rows(&unnamed).unwrap_err().contains("kernel"));
        let empty = vec![Json::obj(vec![("kernel", Json::str("")), ("s", Json::Num(1.0))])];
        assert!(check_perf_rows(&empty).unwrap_err().contains("kernel"));
        // no numeric metric at all
        let nometric = vec![Json::obj(vec![("kernel", Json::str("x"))])];
        assert!(check_perf_rows(&nometric).unwrap_err().contains("numeric"));
        // not an object
        assert!(check_perf_rows(&[Json::Num(3.0)]).unwrap_err().contains("object"));
        // negative duration
        let negsec = vec![Json::obj(vec![
            ("kernel", Json::str("x")),
            ("fwd_seconds", Json::Num(-1.0e-3)),
        ])];
        assert!(check_perf_rows(&negsec).unwrap_err().contains("negative duration"));
        // zero speedup (a degenerate timing slipped through a ratio)
        let zspeed = vec![Json::obj(vec![
            ("kernel", Json::str("x")),
            ("simd_speedup_vs_scalar", Json::Num(0.0)),
        ])];
        assert!(check_perf_rows(&zspeed).unwrap_err().contains("non-positive ratio"));
    }

    #[test]
    fn safe_speedup_guards_degenerate_timings() {
        assert_eq!(safe_speedup(2.0, 1.0), Some(2.0));
        assert_eq!(safe_speedup(1.0, 4.0), Some(0.25));
        // a sub-resolution timer reading must not become an infinite ratio
        assert_eq!(safe_speedup(1.0, 0.0), None);
        assert_eq!(safe_speedup(0.0, 1.0), None);
        assert_eq!(safe_speedup(-1.0, 1.0), None);
        assert_eq!(safe_speedup(f64::NAN, 1.0), None);
        assert_eq!(safe_speedup(1.0, f64::INFINITY), None);
    }

    #[test]
    fn geomean_guards_degenerate_elements() {
        let g = geomean(&[2.0, 8.0]).unwrap();
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[1.5]), Some(1.5));
        assert_eq!(geomean(&[]), None);
        assert_eq!(geomean(&[1.0, 0.0]), None);
        assert_eq!(geomean(&[1.0, -2.0]), None);
        assert_eq!(geomean(&[1.0, f64::NAN]), None);
        assert_eq!(geomean(&[1.0, f64::INFINITY]), None);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(0.002).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }
}
