//! Deterministic sample streams for the coordinator, including the
//! mid-stream domain-shift scenario (the paper's motivating use case:
//! "adapt ... alongside a changing input domain", §I).

use crate::data::Domain;
use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

/// One arrival: a labeled sample and the gap until the next arrival.
pub struct Arrival {
    pub x: TensorF32,
    pub y: usize,
    pub gap_s: f64,
}

/// A finite stream of labeled samples drawn from one or two domains.
pub struct SampleStream<'a> {
    domains: Vec<&'a Domain>,
    /// Arrival index at which the stream switches to the next domain.
    switch_at: usize,
    remaining: usize,
    emitted: usize,
    mean_gap_s: f64,
    rng: Pcg32,
}

impl<'a> SampleStream<'a> {
    /// Single-domain stream of `n` samples with mean inter-arrival gap.
    pub fn new(domain: &'a Domain, n: usize, mean_gap_s: f64, seed: u64) -> SampleStream<'a> {
        SampleStream {
            domains: vec![domain],
            switch_at: usize::MAX,
            remaining: n,
            emitted: 0,
            mean_gap_s,
            rng: Pcg32::new(seed, 0x57),
        }
    }

    /// Stream that switches from `first` to `second` after `switch_at`
    /// arrivals (domain-shift scenario).
    pub fn with_shift(
        first: &'a Domain,
        second: &'a Domain,
        n: usize,
        switch_at: usize,
        mean_gap_s: f64,
        seed: u64,
    ) -> SampleStream<'a> {
        SampleStream {
            domains: vec![first, second],
            switch_at,
            remaining: n,
            emitted: 0,
            mean_gap_s,
            rng: Pcg32::new(seed, 0x57),
        }
    }

    pub fn next_sample(&mut self) -> Option<Arrival> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let dom = if self.emitted >= self.switch_at && self.domains.len() > 1 {
            self.domains[1]
        } else {
            self.domains[0]
        };
        self.emitted += 1;
        let y = self.rng.below(dom.spec.classes as u32) as usize;
        let x = dom.sample(y, &mut self.rng);
        // jittered inter-arrival gap: uniform in [0.5, 1.5] × mean
        let gap_s = self.mean_gap_s * self.rng.uniform(0.5, 1.5) as f64;
        Some(Arrival { x, y, gap_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec_by_name;

    #[test]
    fn stream_emits_exactly_n() {
        let spec = spec_by_name("cifar10").unwrap();
        let dom = Domain::new(&spec, [3, 8, 8], 1);
        let mut s = SampleStream::new(&dom, 25, 0.1, 2);
        let mut count = 0;
        while let Some(a) = s.next_sample() {
            assert!(a.y < 10);
            assert!(a.gap_s >= 0.05 && a.gap_s <= 0.15);
            count += 1;
        }
        assert_eq!(count, 25);
    }

    #[test]
    fn shift_switches_domain() {
        let spec = spec_by_name("cifar10").unwrap();
        let d1 = Domain::new(&spec, [3, 8, 8], 1);
        let d2 = d1.shifted(99);
        let mut s = SampleStream::with_shift(&d1, &d2, 10, 5, 0.1, 3);
        // consume all; just verifies the switch does not panic and labels
        // remain valid (distributional checks live in data::tests)
        let mut n = 0;
        while let Some(a) = s.next_sample() {
            assert!(a.y < 10);
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = spec_by_name("cwru").unwrap();
        let dom = Domain::new(&spec, [1, 1, 64], 4);
        let mut a = SampleStream::new(&dom, 5, 0.1, 7);
        let mut b = SampleStream::new(&dom, 5, 0.1, 7);
        while let (Some(x), Some(y)) = (a.next_sample(), b.next_sample()) {
            assert_eq!(x.y, y.y);
            assert_eq!(x.x.data(), y.x.data());
        }
    }
}
