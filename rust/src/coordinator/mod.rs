//! On-device training coordinator — the L3 runtime lifecycle.
//!
//! The paper's motivating deployment (§I) is an MCU that keeps serving
//! inference while adapting in place: samples arrive from a sensor at some
//! rate, every sample is classified immediately (zero-downtime property),
//! labeled samples are retained in a bounded replay buffer, and training
//! steps are interleaved in the idle time between arrivals.
//!
//! This module provides that lifecycle: a deterministic sample stream
//! (optionally with a mid-stream domain shift — the "changing input
//! domain" scenario), a reservoir-sampling replay buffer, and the
//! [`Coordinator`] that owns the deployed model, the optimizer, the sparse
//! update controller and the telemetry. The simulated clock advances by
//! the device model's cost for every pass, so utilization and energy
//! reports are consistent with the hardware study.

pub mod fleet;
pub mod replay;
pub mod stream;

use crate::device::DeviceModel;
use crate::graph::exec::NativeModel;
use crate::kernels::{softmax, OpCounter};
use crate::memplan::Scratch;
use crate::tensor::TensorF32;
use crate::train::loop_::Sparsity;
use crate::train::Optimizer;
use crate::util::prng::Pcg32;
use replay::ReplayBuffer;
use stream::SampleStream;

/// Telemetry of one coordinator run.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    pub arrivals: u64,
    pub inferences: u64,
    pub correct_online: u64,
    pub train_steps: u64,
    /// Simulated wall-clock spent computing, seconds.
    pub busy_s: f64,
    /// Simulated wall-clock total, seconds.
    pub elapsed_s: f64,
    /// Energy (J), idle included, over the whole run.
    pub energy_j: f64,
    pub fwd_ops: OpCounter,
    pub bwd_ops: OpCounter,
}

impl Telemetry {
    pub fn online_accuracy(&self) -> f32 {
        if self.inferences == 0 {
            0.0
        } else {
            self.correct_online as f32 / self.inferences as f32
        }
    }

    pub fn utilization(&self) -> f64 {
        if self.elapsed_s == 0.0 {
            0.0
        } else {
            self.busy_s / self.elapsed_s
        }
    }

    /// Fold another run's telemetry into this one (fleet aggregation —
    /// see [`fleet::FleetCoordinator`]). Counters and op totals sum;
    /// simulated times and energy sum too, so `elapsed_s` becomes total
    /// simulated device-seconds and [`Telemetry::utilization`] the
    /// fleet-average duty cycle.
    pub fn merge(&mut self, other: &Telemetry) {
        self.arrivals += other.arrivals;
        self.inferences += other.inferences;
        self.correct_online += other.correct_online;
        self.train_steps += other.train_steps;
        self.busy_s += other.busy_s;
        self.elapsed_s += other.elapsed_s;
        self.energy_j += other.energy_j;
        self.fwd_ops.add(&other.fwd_ops);
        self.bwd_ops.add(&other.bwd_ops);
    }
}

/// Policy knobs for the coordinator. `#[non_exhaustive]` so fleet-era
/// knobs can land without breaking downstream literals — construct via
/// [`CoordinatorConfig::builder`] (or start from `default()` with
/// struct-update syntax inside this crate).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct CoordinatorConfig {
    /// Replay-buffer capacity (samples).
    pub replay_capacity: usize,
    /// Training steps attempted per arrival gap (budgeted by idle time).
    pub max_steps_per_gap: usize,
    /// Don't start training until this many samples are buffered.
    pub warmup_samples: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { replay_capacity: 64, max_steps_per_gap: 4, warmup_samples: 8 }
    }
}

impl CoordinatorConfig {
    pub fn builder() -> CoordinatorConfigBuilder {
        CoordinatorConfigBuilder { cfg: CoordinatorConfig::default() }
    }

    /// Clamp the knobs to a self-consistent state: a replay buffer of at
    /// least one slot, and a warmup threshold the buffer can actually
    /// reach (a warmup above capacity would disable training forever).
    pub(crate) fn validated(mut self) -> CoordinatorConfig {
        self.replay_capacity = self.replay_capacity.max(1);
        self.warmup_samples = self.warmup_samples.min(self.replay_capacity);
        self
    }
}

/// Builder for [`CoordinatorConfig`] with validated defaults (see
/// [`CoordinatorConfig::validated`]).
#[derive(Clone, Debug)]
pub struct CoordinatorConfigBuilder {
    cfg: CoordinatorConfig,
}

impl CoordinatorConfigBuilder {
    pub fn replay_capacity(mut self, v: usize) -> Self {
        self.cfg.replay_capacity = v;
        self
    }

    pub fn max_steps_per_gap(mut self, v: usize) -> Self {
        self.cfg.max_steps_per_gap = v;
        self
    }

    pub fn warmup_samples(mut self, v: usize) -> Self {
        self.cfg.warmup_samples = v;
        self
    }

    pub fn build(self) -> CoordinatorConfig {
        self.cfg.validated()
    }
}

/// The on-device lifecycle driver.
pub struct Coordinator<'a> {
    pub model: NativeModel,
    pub device: DeviceModel,
    pub cfg: CoordinatorConfig,
    opt: &'a mut dyn Optimizer,
    sparsity: Sparsity,
    replay: ReplayBuffer,
    rng: Pcg32,
    /// GEMM scratch arena, pre-sized at construction from the model's
    /// compiled execution plan (exact per-op requirements, every
    /// precision) and reused by every inference and training pass of the
    /// run with zero growth.
    scratch: Scratch,
    pub telemetry: Telemetry,
}

/// Builder for [`Coordinator`]: model, device and optimizer are the
/// required inputs; sparsity (default dense), config (validated defaults)
/// and seed (default 0) are optional knobs.
pub struct CoordinatorBuilder<'a> {
    model: NativeModel,
    device: DeviceModel,
    opt: &'a mut dyn Optimizer,
    sparsity: Sparsity,
    cfg: CoordinatorConfig,
    seed: u64,
}

impl<'a> CoordinatorBuilder<'a> {
    pub fn sparsity(mut self, s: Sparsity) -> Self {
        self.sparsity = s;
        self
    }

    pub fn config(mut self, cfg: CoordinatorConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> Coordinator<'a> {
        let cfg = self.cfg.validated();
        let replay = ReplayBuffer::new(cfg.replay_capacity, self.seed ^ 0xBEEF);
        // The run-long GEMM arena; the model's packed-weight cache needs
        // no warming here — `NativeModel::build`/`reset_trainable` leave
        // it warm and `backward_in` re-warms after every optimizer touch.
        let scratch = self.model.make_scratch();
        Coordinator {
            model: self.model,
            device: self.device,
            cfg,
            opt: self.opt,
            sparsity: self.sparsity,
            replay,
            rng: Pcg32::new(self.seed, 0xC0),
            scratch,
            telemetry: Telemetry::default(),
        }
    }
}

impl<'a> Coordinator<'a> {
    pub fn builder(
        model: NativeModel,
        device: DeviceModel,
        opt: &'a mut dyn Optimizer,
    ) -> CoordinatorBuilder<'a> {
        CoordinatorBuilder {
            model,
            device,
            opt,
            sparsity: Sparsity::Dense,
            cfg: CoordinatorConfig::default(),
            seed: 0,
        }
    }

    /// Drive the coordinator over a stream until it is exhausted.
    ///
    /// Per arrival: (1) classify the sample immediately (inference is never
    /// blocked by training — the paper's in-place property means the same
    /// weights serve both); (2) admit it to the replay buffer; (3) spend
    /// the idle time until the next arrival on training steps drawn from
    /// the buffer, bounded by `max_steps_per_gap` and by the simulated
    /// time budget.
    pub fn run(&mut self, stream: &mut SampleStream) -> &Telemetry {
        while let Some(arrival) = stream.next_sample() {
            self.telemetry.arrivals += 1;

            // 1. immediate inference
            let mut fwd = OpCounter::new();
            let trace = self.model.forward_in(&arrival.x, &mut self.scratch, &mut fwd);
            let pred = softmax::predict(&trace.logits);
            self.telemetry.inferences += 1;
            if pred == arrival.y {
                self.telemetry.correct_online += 1;
            }
            let infer_cost = self.device.cost(&fwd);
            self.telemetry.busy_s += infer_cost.seconds;
            self.telemetry.fwd_ops.add(&fwd);

            // 2. retain
            self.replay.push(arrival.x.clone(), arrival.y);

            // 3. train in the gap
            let mut budget = (arrival.gap_s - infer_cost.seconds).max(0.0);
            if self.replay.len() >= self.cfg.warmup_samples {
                for _ in 0..self.cfg.max_steps_per_gap {
                    let Some((x, y)) = self.replay.draw(&mut self.rng) else { break };
                    let (step_s, fwd_ops, bwd_ops) = self.train_one(&x, y);
                    if step_s > budget {
                        // would overrun the gap: step still completes (the
                        // sample queue absorbs it) but stop training
                        self.telemetry.busy_s += step_s;
                        self.telemetry.fwd_ops.add(&fwd_ops);
                        self.telemetry.bwd_ops.add(&bwd_ops);
                        self.telemetry.train_steps += 1;
                        budget = 0.0;
                        break;
                    }
                    budget -= step_s;
                    self.telemetry.busy_s += step_s;
                    self.telemetry.fwd_ops.add(&fwd_ops);
                    self.telemetry.bwd_ops.add(&bwd_ops);
                    self.telemetry.train_steps += 1;
                }
            }
            self.telemetry.elapsed_s += arrival.gap_s.max(infer_cost.seconds);
        }
        self.opt.finish(&mut self.model, &mut self.telemetry.bwd_ops);
        // energy: active during busy time, idle otherwise
        let d = &self.device;
        let idle = (self.telemetry.elapsed_s - self.telemetry.busy_s).max(0.0);
        self.telemetry.energy_j = (d.idle_a + d.active_delta_a) * d.volts * self.telemetry.busy_s
            + d.idle_a * d.volts * idle;
        &self.telemetry
    }

    fn train_one(&mut self, x: &TensorF32, y: usize) -> (f64, OpCounter, OpCounter) {
        let mut fwd = OpCounter::new();
        let mut bwd = OpCounter::new();
        let trace = self.model.forward_adapt_in(x, &mut self.scratch, &mut fwd);
        let (loss, _, err) = softmax::softmax_ce(&trace.logits, y, &mut bwd);
        let res = match &mut self.sparsity {
            Sparsity::Dense => self.model.backward_in(
                &trace,
                err,
                &mut crate::graph::exec::DenseUpdates,
                &mut self.scratch,
                &mut bwd,
            ),
            Sparsity::Dynamic(ctl) => {
                ctl.begin_sample(loss);
                self.model.backward_in(&trace, err, ctl, &mut self.scratch, &mut bwd)
            }
        };
        self.opt.accumulate(&mut self.model, &res, &mut bwd);
        let secs = self.device.cost(&fwd).seconds + self.device.cost(&bwd).seconds;
        (secs, fwd, bwd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{spec_by_name, Domain};
    use crate::device;
    use crate::graph::exec::{calibrate, FloatParams};
    use crate::graph::{models, DnnConfig};
    use crate::train::fqt::FqtSgd;

    fn deployed() -> (NativeModel, Domain) {
        let spec = spec_by_name("cifar10").unwrap();
        let dom = Domain::new(&spec, [3, 12, 12], 5);
        let mut rng = Pcg32::seeded(17);
        let def = models::mnist_cnn(&[3, 12, 12], 10);
        let fp = FloatParams::init(&def, &mut rng);
        let (cal_split, _) = dom.splits(1, 0, &mut rng);
        let calib = calibrate(&def, &fp, &cal_split.xs);
        (NativeModel::build(def, DnnConfig::Uint8, &fp, &calib), dom)
    }

    #[test]
    fn coordinator_processes_all_arrivals() {
        let (m, dom) = deployed();
        let mut opt = FqtSgd::new(&m, 0.01, 4);
        let mut coord = Coordinator::builder(m, device::imxrt1062(), &mut opt).seed(1).build();
        let mut stream = SampleStream::new(&dom, 60, 0.05, 2);
        let t = coord.run(&mut stream);
        assert_eq!(t.arrivals, 60);
        assert_eq!(t.inferences, 60);
        assert!(t.train_steps > 0, "idle gaps must be used for training");
        assert!(t.elapsed_s > 0.0 && t.busy_s > 0.0);
        assert!(t.energy_j > 0.0);
        assert!(t.utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn online_accuracy_improves_over_stream() {
        let (m, dom) = deployed();
        let mut opt = FqtSgd::new(&m, 0.01, 4);
        let mut coord = Coordinator::builder(m, device::imxrt1062(), &mut opt)
            .config(CoordinatorConfig::builder().warmup_samples(4).build())
            .seed(2)
            .build();
        // first half of the stream
        let mut s1 = SampleStream::new(&dom, 150, 0.05, 3);
        coord.run(&mut s1);
        let first = coord.telemetry.clone();
        // second half: fresh telemetry window
        coord.telemetry = Telemetry::default();
        let mut s2 = SampleStream::new(&dom, 150, 0.05, 4);
        coord.run(&mut s2);
        let second = &coord.telemetry;
        assert!(
            second.online_accuracy() > first.online_accuracy().max(0.2),
            "{} -> {}",
            first.online_accuracy(),
            second.online_accuracy()
        );
    }

    #[test]
    fn slow_arrival_rate_caps_training_steps() {
        let (m, dom) = deployed();
        let mut opt = FqtSgd::new(&m, 0.01, 4);
        let cfg = CoordinatorConfig::builder().max_steps_per_gap(2).build();
        let mut coord =
            Coordinator::builder(m, device::imxrt1062(), &mut opt).config(cfg).seed(3).build();
        let mut stream = SampleStream::new(&dom, 40, 1.0, 5);
        let t = coord.run(&mut stream);
        assert!(t.train_steps <= 2 * t.arrivals);
        // with 1s gaps on an M7 the device is mostly idle
        assert!(t.utilization() < 0.5, "util={}", t.utilization());
    }

    #[test]
    fn tight_gaps_throttle_training() {
        let (m, dom) = deployed();
        let mut opt = FqtSgd::new(&m, 0.01, 4);
        let cfg = CoordinatorConfig::builder().max_steps_per_gap(8).build();
        // RP2040 is slow; near-zero gaps leave no idle budget
        let mut coord =
            Coordinator::builder(m, device::rp2040(), &mut opt).config(cfg).seed(4).build();
        let mut stream = SampleStream::new(&dom, 30, 1e-6, 6);
        let t = coord.run(&mut stream);
        // at most one (overrunning) step per gap once warm
        assert!(t.train_steps <= t.arrivals, "steps={} arrivals={}", t.train_steps, t.arrivals);
    }

    #[test]
    fn telemetry_guards_zero_samples() {
        let t = Telemetry::default();
        assert_eq!(t.online_accuracy(), 0.0);
        assert_eq!(t.utilization(), 0.0);
    }

    #[test]
    fn telemetry_accuracy_and_utilization_accounting() {
        let mut t = Telemetry { inferences: 8, correct_online: 6, ..Default::default() };
        t.busy_s = 1.0;
        t.elapsed_s = 4.0;
        assert!((t.online_accuracy() - 0.75).abs() < 1e-6);
        assert!((t.utilization() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn telemetry_merge_sums_fields() {
        let mut a = Telemetry {
            arrivals: 3,
            inferences: 3,
            correct_online: 2,
            train_steps: 5,
            busy_s: 1.0,
            elapsed_s: 2.0,
            energy_j: 0.5,
            ..Default::default()
        };
        a.fwd_ops.int_macs = 100;
        let mut b = Telemetry {
            arrivals: 1,
            inferences: 1,
            correct_online: 1,
            train_steps: 2,
            busy_s: 0.5,
            elapsed_s: 2.0,
            energy_j: 0.25,
            ..Default::default()
        };
        b.fwd_ops.int_macs = 40;
        b.bwd_ops.int_macs = 7;
        a.merge(&b);
        assert_eq!(a.arrivals, 4);
        assert_eq!(a.inferences, 4);
        assert_eq!(a.correct_online, 3);
        assert_eq!(a.train_steps, 7);
        assert!((a.busy_s - 1.5).abs() < 1e-12);
        assert!((a.elapsed_s - 4.0).abs() < 1e-12);
        assert!((a.energy_j - 0.75).abs() < 1e-12);
        assert_eq!(a.fwd_ops.int_macs, 140);
        assert_eq!(a.bwd_ops.int_macs, 7);
        // merged utilization = fleet-average duty cycle
        assert!((a.utilization() - 1.5 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn config_builder_validates() {
        let c = CoordinatorConfig::builder().replay_capacity(0).warmup_samples(99).build();
        assert_eq!(c.replay_capacity, 1);
        assert_eq!(c.warmup_samples, 1, "warmup must be reachable within capacity");
    }
}
