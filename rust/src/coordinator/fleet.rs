//! Fleet-scale multi-tenant coordinator: N independent device sessions
//! driven concurrently over the persistent [`WorkerPool`].
//!
//! The paper's motivating scenario (§I) — and the "millions of devices"
//! framing of Lin et al.'s 256KB on-device training work — is a *fleet*
//! of deployed MCUs, each adapting in place to its own drifting sensor
//! stream. [`super::Coordinator`] simulates one such device; this module
//! scales the simulation out:
//!
//!  * one [`ModelArtifacts`] deployment is shared behind an `Arc` by
//!    every tenant — definition, compiled plan, PTQ calibration and base
//!    weights are paid for once, fleet-wide;
//!  * each [`TenantSession`] owns only its mutable per-device state: the
//!    Arc-CoW parameter clones (aliasing the base until the optimizer's
//!    first write), adapted activation ranges, error observers, pack
//!    cache, replay buffer, sparse-update controller, RNGs and telemetry
//!    — so per-tenant memory is deltas + replay, not a model copy;
//!  * [`FleetCoordinator::run`] shards whole tenants across the worker
//!    pool. Every tenant's trajectory depends only on the shared
//!    artifacts and its own seeds (worker scratch arenas are fully
//!    overwritten per pass), so per-tenant results are **bit-identical
//!    for every worker count and sharding** — the PR 1/4 `TT_WORKERS`
//!    determinism contract, generalized from batch samples to tenants.
//!
//! Per-tenant domain shift: each tenant's stream switches, at
//! [`FleetConfig::shift_at`], from the fleet's base domain to one of a
//! small pool of shifted variants ([`FleetConfig::shift_pool`], assigned
//! round-robin by tenant id) — distinct drift per tenant without paying
//! for 10k distinct domain prototype sets.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::replay::ReplayBuffer;
use crate::coordinator::stream::SampleStream;
use crate::coordinator::{CoordinatorConfig, Telemetry};
use crate::data::Domain;
use crate::device::DeviceModel;
use crate::graph::batch::{ScopedJob, WorkerPool};
use crate::graph::exec::{DenseUpdates, ModelArtifacts, NativeModel};
use crate::kernels::{softmax, OpCounter};
use crate::memplan::Scratch;
use crate::tensor::TensorF32;
use crate::train::fqt::FqtSgd;
use crate::train::loop_::Sparsity;
use crate::train::sparse::DynamicSparse;
use crate::train::Optimizer;
use crate::util::prng::Pcg32;

/// Per-tenant seed derivation: every tenant RNG stream is a pure function
/// of the fleet seed and the tenant id, so a tenant's trajectory is
/// reproducible standalone (the determinism tests re-run single tenants
/// and demand bit-identical weights).
fn tenant_seed(fleet_seed: u64, id: usize) -> u64 {
    fleet_seed.wrapping_add((id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Fleet policy knobs. `#[non_exhaustive]`; construct via
/// [`FleetConfig::builder`].
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct FleetConfig {
    /// Number of tenant sessions.
    pub tenants: usize,
    /// Stream length per tenant.
    pub arrivals_per_tenant: usize,
    /// Mean inter-arrival gap per tenant stream, seconds (simulated).
    pub mean_gap_s: f64,
    /// Arrival index at which a tenant's domain shifts (`usize::MAX` =
    /// no shift).
    pub shift_at: usize,
    /// Number of distinct shifted-domain variants shared across the
    /// fleet (tenant `id` drifts to variant `id % shift_pool`).
    pub shift_pool: usize,
    /// Per-tenant optimizer learning rate.
    pub lr: f32,
    /// Per-tenant optimizer minibatch size.
    pub batch: usize,
    /// Sparse-update floor (λ_min; ≥ 1.0 = dense updates).
    pub lambda_min: f32,
    /// Per-tenant coordinator lifecycle knobs (replay capacity, steps
    /// per gap, warmup).
    pub session: CoordinatorConfig,
    /// Fleet seed; every tenant seed derives from it and the tenant id.
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            tenants: 1,
            arrivals_per_tenant: 50,
            mean_gap_s: 0.05,
            shift_at: usize::MAX,
            shift_pool: 8,
            lr: 0.01,
            batch: 8,
            lambda_min: 1.0,
            session: CoordinatorConfig::default(),
            seed: 1,
        }
    }
}

impl FleetConfig {
    pub fn builder() -> FleetConfigBuilder {
        FleetConfigBuilder { cfg: FleetConfig::default() }
    }
}

/// Builder for [`FleetConfig`] with validated defaults.
#[derive(Clone, Debug)]
pub struct FleetConfigBuilder {
    cfg: FleetConfig,
}

impl FleetConfigBuilder {
    pub fn tenants(mut self, v: usize) -> Self {
        self.cfg.tenants = v;
        self
    }

    pub fn arrivals_per_tenant(mut self, v: usize) -> Self {
        self.cfg.arrivals_per_tenant = v;
        self
    }

    pub fn mean_gap_s(mut self, v: f64) -> Self {
        self.cfg.mean_gap_s = v;
        self
    }

    pub fn shift_at(mut self, v: usize) -> Self {
        self.cfg.shift_at = v;
        self
    }

    pub fn shift_pool(mut self, v: usize) -> Self {
        self.cfg.shift_pool = v;
        self
    }

    pub fn lr(mut self, v: f32) -> Self {
        self.cfg.lr = v;
        self
    }

    pub fn batch(mut self, v: usize) -> Self {
        self.cfg.batch = v;
        self
    }

    pub fn lambda_min(mut self, v: f32) -> Self {
        self.cfg.lambda_min = v;
        self
    }

    pub fn session(mut self, v: CoordinatorConfig) -> Self {
        self.cfg.session = v;
        self
    }

    pub fn seed(mut self, v: u64) -> Self {
        self.cfg.seed = v;
        self
    }

    pub fn build(self) -> FleetConfig {
        let mut cfg = self.cfg;
        cfg.shift_pool = cfg.shift_pool.max(1);
        cfg.batch = cfg.batch.max(1);
        cfg.session = cfg.session.validated();
        cfg
    }
}

/// One simulated device: the per-tenant session state plus its lifecycle
/// driver — the exact per-arrival loop of [`super::Coordinator::run`]
/// (immediate inference, replay admission, idle-gap training), run
/// against a caller-provided scratch arena so ten thousand tenants share
/// a handful of worker arenas instead of owning one each.
pub struct TenantSession {
    pub id: usize,
    /// The tenant's session bound to the shared artifacts
    /// (`model.shared` is the fleet-wide `Arc`; `model.state` is this
    /// tenant's own).
    pub model: NativeModel,
    opt: FqtSgd,
    sparsity: Sparsity,
    replay: ReplayBuffer,
    rng: Pcg32,
    /// Which shifted-domain variant this tenant drifts to.
    shift_idx: usize,
    stream_seed: u64,
    pub telemetry: Telemetry,
}

impl TenantSession {
    /// Spawn a tenant off the shared deployment. Cheap: the session's
    /// parameters are Arc-CoW clones of the base weights and its pack
    /// cache starts cold (the first backward pass warms it).
    pub fn spawn(shared: &Arc<ModelArtifacts>, id: usize, cfg: &FleetConfig) -> TenantSession {
        let model = NativeModel::from_artifacts(Arc::clone(shared));
        let opt = FqtSgd::new(&model, cfg.lr, cfg.batch);
        let seed = tenant_seed(cfg.seed, id);
        TenantSession {
            id,
            opt,
            sparsity: if cfg.lambda_min >= 1.0 {
                Sparsity::Dense
            } else {
                Sparsity::Dynamic(DynamicSparse::new(cfg.lambda_min, 1.0))
            },
            replay: ReplayBuffer::new(cfg.session.replay_capacity, seed ^ 0xBEEF),
            rng: Pcg32::new(seed, 0xC0),
            shift_idx: id % cfg.shift_pool.max(1),
            stream_seed: seed ^ 0x51AE,
            telemetry: Telemetry::default(),
            model,
        }
    }

    /// Bytes this tenant owns beyond the shared artifacts: CoW-diverged
    /// weights, adapted ranges, observers, versions, pack cache
    /// ([`crate::graph::exec::SessionState::delta_bytes`]) plus the
    /// replay buffer's sample storage. Optimizer gradient buffers are
    /// reported separately ([`TenantSession::optimizer_bytes`]) — they
    /// are per-tenant too, but sized by the trainable tail and identical
    /// under shared-artifact and independent deployment alike, so they
    /// stay out of the sharing-ratio accounting.
    pub fn session_bytes(&self) -> usize {
        self.model.state.delta_bytes(&self.model.shared) + self.replay.bytes()
    }

    /// Bytes of this tenant's optimizer state (gradient buffers over the
    /// trainable tail).
    pub fn optimizer_bytes(&self) -> usize {
        self.opt.state_bytes()
    }

    /// Drive this tenant over its whole stream (base domain, shifting to
    /// its pool variant at `cfg.shift_at`). Mirrors
    /// [`super::Coordinator::run`] per arrival: classify immediately,
    /// admit to replay, then spend the idle gap on training steps drawn
    /// from the buffer, bounded by `max_steps_per_gap` and the simulated
    /// time budget.
    pub fn run_stream(
        &mut self,
        base: &Domain,
        shift_pool: &[Domain],
        device: &DeviceModel,
        cfg: &FleetConfig,
        scratch: &mut Scratch,
    ) {
        let shifted = if shift_pool.is_empty() {
            base
        } else {
            &shift_pool[self.shift_idx % shift_pool.len()]
        };
        let mut stream = SampleStream::with_shift(
            base,
            shifted,
            cfg.arrivals_per_tenant,
            cfg.shift_at,
            cfg.mean_gap_s,
            self.stream_seed,
        );
        while let Some(arrival) = stream.next_sample() {
            self.telemetry.arrivals += 1;

            // 1. immediate inference (never blocked by training)
            let mut fwd = OpCounter::new();
            let trace = self.model.forward_in(&arrival.x, scratch, &mut fwd);
            let pred = softmax::predict(&trace.logits);
            self.telemetry.inferences += 1;
            if pred == arrival.y {
                self.telemetry.correct_online += 1;
            }
            let infer_cost = device.cost(&fwd);
            self.telemetry.busy_s += infer_cost.seconds;
            self.telemetry.fwd_ops.add(&fwd);

            // 2. retain
            self.replay.push(arrival.x.clone(), arrival.y);

            // 3. train in the gap
            let mut budget = (arrival.gap_s - infer_cost.seconds).max(0.0);
            if self.replay.len() >= cfg.session.warmup_samples {
                for _ in 0..cfg.session.max_steps_per_gap {
                    let Some((x, y)) = self.replay.draw(&mut self.rng) else { break };
                    let (step_s, fwd_ops, bwd_ops) = self.train_one(&x, y, device, scratch);
                    self.telemetry.busy_s += step_s;
                    self.telemetry.fwd_ops.add(&fwd_ops);
                    self.telemetry.bwd_ops.add(&bwd_ops);
                    self.telemetry.train_steps += 1;
                    if step_s > budget {
                        // overruns the gap: the step still completes, but
                        // stop training until the next arrival
                        budget = 0.0;
                        break;
                    }
                    budget -= step_s;
                }
            }
            self.telemetry.elapsed_s += arrival.gap_s.max(infer_cost.seconds);
        }
        self.opt.finish(&mut self.model, &mut self.telemetry.bwd_ops);
        // energy: active during busy time, idle otherwise
        let idle = (self.telemetry.elapsed_s - self.telemetry.busy_s).max(0.0);
        self.telemetry.energy_j = (device.idle_a + device.active_delta_a)
            * device.volts
            * self.telemetry.busy_s
            + device.idle_a * device.volts * idle;
    }

    fn train_one(
        &mut self,
        x: &TensorF32,
        y: usize,
        device: &DeviceModel,
        scratch: &mut Scratch,
    ) -> (f64, OpCounter, OpCounter) {
        let mut fwd = OpCounter::new();
        let mut bwd = OpCounter::new();
        let trace = self.model.forward_adapt_in(x, scratch, &mut fwd);
        let (loss, _, err) = softmax::softmax_ce(&trace.logits, y, &mut bwd);
        let res = match &mut self.sparsity {
            Sparsity::Dense => {
                self.model.backward_in(&trace, err, &mut DenseUpdates, scratch, &mut bwd)
            }
            Sparsity::Dynamic(ctl) => {
                ctl.begin_sample(loss);
                self.model.backward_in(&trace, err, ctl, scratch, &mut bwd)
            }
        };
        self.opt.accumulate(&mut self.model, &res, &mut bwd);
        let secs = device.cost(&fwd).seconds + device.cost(&bwd).seconds;
        (secs, fwd, bwd)
    }
}

/// Aggregate result of one fleet run: merged telemetry plus the memory
/// accounting behind the "per-tenant memory is deltas + replay" claim.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub tenants: usize,
    /// All tenant telemetry merged ([`Telemetry::merge`]): totals over
    /// the fleet; `online_accuracy` is the fleet-aggregate online
    /// accuracy under per-tenant domain shift.
    pub aggregate: Telemetry,
    /// Bytes of deployment state shared fleet-wide (base weights + the
    /// plan's activation arena requirement).
    pub shared_bytes: usize,
    /// Σ per-tenant session bytes (CoW deltas + replay buffers).
    pub session_bytes: usize,
    /// Σ per-tenant optimizer gradient-buffer bytes (trainable tail
    /// only). Identical under shared and independent deployment, so
    /// reported alongside the ratio rather than inside it.
    pub optimizer_bytes: usize,
    /// What this fleet actually costs: `shared_bytes + session_bytes`.
    pub fleet_bytes: usize,
    /// What N independent single-tenant deployments would cost:
    /// `tenants × shared_bytes + session_bytes`.
    pub independent_bytes: usize,
}

impl FleetReport {
    /// Mean per-tenant session overhead, bytes.
    pub fn per_tenant_bytes(&self) -> usize {
        self.session_bytes / self.tenants.max(1)
    }

    /// Memory ratio of N independent deployments over the shared-plan
    /// fleet (machine-independent: pure byte accounting). > 1 whenever
    /// sharing saves anything; grows with N as the shared artifacts
    /// amortize.
    pub fn memory_ratio(&self) -> f64 {
        self.independent_bytes as f64 / self.fleet_bytes.max(1) as f64
    }
}

/// Drives N tenant sessions over one shared deployment. Consumes the
/// typed [`RunConfig`] (worker count) plus the fleet policy knobs.
pub struct FleetCoordinator {
    shared: Arc<ModelArtifacts>,
    device: DeviceModel,
    base: Domain,
    shift_domains: Vec<Domain>,
    run_cfg: RunConfig,
    cfg: FleetConfig,
    pub tenants: Vec<TenantSession>,
}

impl FleetCoordinator {
    /// Build the fleet: derive the shifted-domain pool from the base
    /// domain and spawn `cfg.tenants` sessions off the shared artifacts.
    pub fn new(
        shared: Arc<ModelArtifacts>,
        device: DeviceModel,
        base: Domain,
        run_cfg: RunConfig,
        cfg: FleetConfig,
    ) -> FleetCoordinator {
        let pool_n = cfg.shift_pool.max(1).min(cfg.tenants.max(1));
        let shift_domains: Vec<Domain> =
            (0..pool_n).map(|i| base.shifted(cfg.seed ^ 0x5157_0000 ^ i as u64)).collect();
        let tenants: Vec<TenantSession> =
            (0..cfg.tenants).map(|id| TenantSession::spawn(&shared, id, &cfg)).collect();
        FleetCoordinator { shared, device, base, shift_domains, run_cfg, cfg, tenants }
    }

    pub fn shared(&self) -> &Arc<ModelArtifacts> {
        &self.shared
    }

    pub fn device(&self) -> &DeviceModel {
        &self.device
    }

    pub fn base(&self) -> &Domain {
        &self.base
    }

    pub fn shift_domains(&self) -> &[Domain] {
        &self.shift_domains
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Run every tenant's stream to exhaustion, sharding whole tenants
    /// across `run_cfg.workers` pool threads (1 = inline on this
    /// thread). Tenants are mutually independent and worker scratch is
    /// fully overwritten per pass, so per-tenant results are
    /// bit-identical for every worker count.
    pub fn run(&mut self) -> FleetReport {
        let workers = self.run_cfg.workers.max(1);
        let base = &self.base;
        let doms = &self.shift_domains[..];
        let device = &self.device;
        let cfg = &self.cfg;
        if workers <= 1 || self.tenants.len() <= 1 {
            let mut scratch = self.shared.make_scratch();
            for t in self.tenants.iter_mut() {
                t.run_stream(base, doms, device, cfg, &mut scratch);
            }
        } else {
            let mut pool = WorkerPool::new(workers);
            let chunk = self.tenants.len().div_ceil(workers).max(1);
            let jobs: Vec<ScopedJob<'_>> = self
                .tenants
                .chunks_mut(chunk)
                .map(|slice| {
                    Box::new(move |scratch: &mut Scratch| {
                        for t in slice.iter_mut() {
                            t.run_stream(base, doms, device, cfg, scratch);
                        }
                    }) as ScopedJob<'_>
                })
                .collect();
            pool.run_scope(jobs);
        }
        self.report()
    }

    /// Aggregate telemetry and memory accounting over the current tenant
    /// state (called by [`FleetCoordinator::run`]; callable standalone
    /// after partial runs).
    pub fn report(&self) -> FleetReport {
        let mut aggregate = Telemetry::default();
        let mut session_bytes = 0usize;
        let mut optimizer_bytes = 0usize;
        for t in &self.tenants {
            aggregate.merge(&t.telemetry);
            session_bytes += t.session_bytes();
            optimizer_bytes += t.optimizer_bytes();
        }
        let shared_bytes = self.shared.shared_bytes();
        FleetReport {
            tenants: self.tenants.len(),
            aggregate,
            shared_bytes,
            session_bytes,
            optimizer_bytes,
            fleet_bytes: shared_bytes + session_bytes,
            independent_bytes: self.tenants.len() * shared_bytes + session_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec_by_name;
    use crate::device;
    use crate::graph::exec::{calibrate, FloatParams};
    use crate::graph::{models, DnnConfig};

    fn deploy() -> (Arc<ModelArtifacts>, Domain) {
        let spec = spec_by_name("cifar10").unwrap();
        let dom = Domain::new(&spec, [3, 12, 12], 5);
        let mut rng = Pcg32::seeded(17);
        let def = models::mnist_cnn(&[3, 12, 12], 10);
        let fp = FloatParams::init(&def, &mut rng);
        let (cal, _) = dom.splits(1, 0, &mut rng);
        let calib = calibrate(&def, &fp, &cal.xs);
        (Arc::new(ModelArtifacts::deploy(def, DnnConfig::Uint8, &fp, &calib)), dom)
    }

    #[test]
    fn fleet_processes_every_tenant_stream() {
        let (shared, dom) = deploy();
        let cfg = FleetConfig::builder()
            .tenants(4)
            .arrivals_per_tenant(12)
            .shift_at(6)
            .session(CoordinatorConfig::builder().warmup_samples(2).build())
            .build();
        let run_cfg = RunConfig::builder().workers(2).build();
        let mut fleet = FleetCoordinator::new(shared, device::imxrt1062(), dom, run_cfg, cfg);
        let rep = fleet.run();
        assert_eq!(rep.tenants, 4);
        assert_eq!(rep.aggregate.arrivals, 48);
        assert_eq!(rep.aggregate.inferences, 48);
        assert!(rep.aggregate.train_steps > 0, "idle gaps must be used for training");
        assert!(rep.aggregate.utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn shared_plan_fleet_is_cheaper_than_independent_models() {
        let (shared, dom) = deploy();
        let cfg = FleetConfig::builder()
            .tenants(8)
            .arrivals_per_tenant(6)
            .session(CoordinatorConfig::builder().warmup_samples(2).replay_capacity(4).build())
            .build();
        let mut fleet =
            FleetCoordinator::new(shared, device::imxrt1062(), dom, RunConfig::default(), cfg);
        let rep = fleet.run();
        assert!(rep.fleet_bytes < rep.independent_bytes);
        assert!(rep.memory_ratio() > 1.0, "ratio={}", rep.memory_ratio());
        // every tenant owns deltas + replay, not a model copy
        assert!(
            rep.per_tenant_bytes() < rep.shared_bytes,
            "per-tenant state must stay below a full model copy"
        );
    }

    #[test]
    fn spawning_a_session_is_deltas_only() {
        let (shared, _) = deploy();
        let cfg = FleetConfig::default();
        let t = TenantSession::spawn(&shared, 0, &cfg);
        // Fresh session: every weight tensor still aliases the base
        // image, the pack cache is cold, the replay buffer empty — the
        // SessionState's own bytes are ranges/observers/version
        // bookkeeping only (optimizer gradient buffers are accounted
        // separately from session_bytes).
        let state_only = t.model.state.delta_bytes(&t.model.shared);
        assert!(
            state_only < 2048,
            "fresh session state owns {state_only} bytes, expected bookkeeping only"
        );
        assert_eq!(t.session_bytes(), state_only, "empty replay adds nothing");
        assert!(t.optimizer_bytes() > 0, "trainable model must carry gradient buffers");
    }
}
