//! Bounded replay buffer with reservoir sampling.
//!
//! On-device training needs labeled data retained in RAM (§I-A, third
//! memory aspect). Capacity is fixed; once full, reservoir sampling keeps
//! an unbiased subset of everything seen so far, which protects the
//! training distribution when the stream is long.

use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

pub struct ReplayBuffer {
    cap: usize,
    seen: u64,
    items: Vec<(TensorF32, usize)>,
    rng: Pcg32,
}

impl ReplayBuffer {
    pub fn new(cap: usize, seed: u64) -> ReplayBuffer {
        ReplayBuffer { cap: cap.max(1), seen: 0, items: Vec::new(), rng: Pcg32::new(seed, 0xEB) }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Admit a sample (reservoir policy once full).
    pub fn push(&mut self, x: TensorF32, y: usize) {
        self.seen += 1;
        if self.items.len() < self.cap {
            self.items.push((x, y));
        } else {
            // replace a random slot with probability cap/seen
            let j = self.rng.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.items[j as usize] = (x, y);
            }
        }
    }

    /// Draw a uniformly random retained sample.
    pub fn draw(&mut self, rng: &mut Pcg32) -> Option<(TensorF32, usize)> {
        if self.items.is_empty() {
            return None;
        }
        let i = rng.below(self.items.len() as u32) as usize;
        Some(self.items[i].clone())
    }

    /// Bytes of sample storage currently held.
    pub fn bytes(&self) -> usize {
        self.items.iter().map(|(x, _)| x.len() * 4 + 8).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(v: f32) -> TensorF32 {
        TensorF32::from_vec(&[2], vec![v, v])
    }

    #[test]
    fn fills_to_capacity_then_stays_bounded() {
        let mut rb = ReplayBuffer::new(8, 1);
        for i in 0..100 {
            rb.push(sample(i as f32), i % 3);
        }
        assert_eq!(rb.len(), 8);
        assert_eq!(rb.seen(), 100);
    }

    #[test]
    fn reservoir_keeps_late_samples_sometimes() {
        let mut rb = ReplayBuffer::new(16, 2);
        for i in 0..400 {
            rb.push(sample(i as f32), 0);
        }
        // with 400 seen and cap 16, expect at least one retained sample
        // from the last half (probability of none is astronomically small)
        let late = rb.items.iter().filter(|(x, _)| x.data()[0] >= 200.0).count();
        assert!(late > 0);
    }

    #[test]
    fn draw_none_when_empty_some_after_push() {
        let mut rb = ReplayBuffer::new(4, 3);
        let mut rng = Pcg32::seeded(9);
        assert!(rb.draw(&mut rng).is_none());
        rb.push(sample(1.0), 7);
        let (x, y) = rb.draw(&mut rng).unwrap();
        assert_eq!(y, 7);
        assert_eq!(x.data()[0], 1.0);
    }

    #[test]
    fn bytes_accounts_storage() {
        let mut rb = ReplayBuffer::new(4, 4);
        rb.push(sample(1.0), 0);
        rb.push(sample(2.0), 1);
        assert_eq!(rb.bytes(), 2 * (2 * 4 + 8));
    }
}
