//! Bounded replay buffer with reservoir sampling.
//!
//! On-device training needs labeled data retained in RAM (§I-A, third
//! memory aspect). Capacity is fixed; once full, reservoir sampling keeps
//! an unbiased subset of everything seen so far, which protects the
//! training distribution when the stream is long.

use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

pub struct ReplayBuffer {
    cap: usize,
    seen: u64,
    items: Vec<(TensorF32, usize)>,
    rng: Pcg32,
}

impl ReplayBuffer {
    pub fn new(cap: usize, seed: u64) -> ReplayBuffer {
        ReplayBuffer { cap: cap.max(1), seen: 0, items: Vec::new(), rng: Pcg32::new(seed, 0xEB) }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Admit a sample (reservoir policy once full).
    pub fn push(&mut self, x: TensorF32, y: usize) {
        self.seen += 1;
        if self.items.len() < self.cap {
            self.items.push((x, y));
        } else {
            // replace a random slot with probability cap/seen
            let j = self.rng.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.items[j as usize] = (x, y);
            }
        }
    }

    /// Draw a uniformly random retained sample.
    pub fn draw(&mut self, rng: &mut Pcg32) -> Option<(TensorF32, usize)> {
        if self.items.is_empty() {
            return None;
        }
        let i = rng.below(self.items.len() as u32) as usize;
        Some(self.items[i].clone())
    }

    /// Bytes of sample storage currently held.
    pub fn bytes(&self) -> usize {
        self.items.iter().map(|(x, _)| x.len() * 4 + 8).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{shrink_dim, Prop};

    fn sample(v: f32) -> TensorF32 {
        TensorF32::from_vec(&[2], vec![v, v])
    }

    #[test]
    fn fills_to_capacity_then_stays_bounded() {
        let mut rb = ReplayBuffer::new(8, 1);
        for i in 0..100 {
            rb.push(sample(i as f32), i % 3);
        }
        assert_eq!(rb.len(), 8);
        assert_eq!(rb.seen(), 100);
    }

    #[test]
    fn reservoir_keeps_late_samples_sometimes() {
        let mut rb = ReplayBuffer::new(16, 2);
        for i in 0..400 {
            rb.push(sample(i as f32), 0);
        }
        // with 400 seen and cap 16, expect at least one retained sample
        // from the last half (probability of none is astronomically small)
        let late = rb.items.iter().filter(|(x, _)| x.data()[0] >= 200.0).count();
        assert!(late > 0);
    }

    #[test]
    fn draw_none_when_empty_some_after_push() {
        let mut rb = ReplayBuffer::new(4, 3);
        let mut rng = Pcg32::seeded(9);
        assert!(rb.draw(&mut rng).is_none());
        rb.push(sample(1.0), 7);
        let (x, y) = rb.draw(&mut rng).unwrap();
        assert_eq!(y, 7);
        assert_eq!(x.data()[0], 1.0);
    }

    #[test]
    fn bytes_accounts_storage() {
        let mut rb = ReplayBuffer::new(4, 4);
        rb.push(sample(1.0), 0);
        rb.push(sample(2.0), 1);
        assert_eq!(rb.bytes(), 2 * (2 * 4 + 8));
    }

    /// Reservoir statistics under fixed seeds: with capacity C and a stream
    /// of N items, every position must be retained with probability ≈ C/N —
    /// early and late items alike (the unbiasedness that protects the
    /// training distribution on long streams).
    #[test]
    fn reservoir_retention_is_unbiased_across_positions() {
        let (cap, n, runs) = (6usize, 60usize, 400usize);
        let expected = cap as f32 / n as f32; // 0.1
        let mut early_hits = 0usize;
        let mut late_hits = 0usize;
        for seed in 0..runs {
            let mut rb = ReplayBuffer::new(cap, seed as u64);
            for i in 0..n {
                rb.push(sample(i as f32), 0);
            }
            if rb.items.iter().any(|(x, _)| x.data()[0] == 3.0) {
                early_hits += 1;
            }
            if rb.items.iter().any(|(x, _)| x.data()[0] == 50.0) {
                late_hits += 1;
            }
        }
        let early = early_hits as f32 / runs as f32;
        let late = late_hits as f32 / runs as f32;
        // ±6 percentage points around the 10% expectation (≈4σ for 400
        // Bernoulli trials) keeps this deterministic-seed test robust.
        assert!((early - expected).abs() < 0.06, "early retention {early} vs {expected}");
        assert!((late - expected).abs() < 0.06, "late retention {late} vs {expected}");
    }

    /// Bounded-capacity property: for any (cap, stream length), the buffer
    /// holds exactly min(cap, len) items, has seen the whole stream, and
    /// every retained item came from the stream.
    #[test]
    fn prop_reservoir_bounded_and_consistent() {
        Prop::new(64).check(
            |r: &mut Pcg32| {
                (1 + r.below(20) as usize, r.below(100) as usize, r.next_u64())
            },
            |&(cap, n, s)| {
                let mut v = Vec::new();
                for c2 in shrink_dim(cap, 1) {
                    v.push((c2, n, s));
                }
                for n2 in shrink_dim(n, 0) {
                    v.push((cap, n2, s));
                }
                v
            },
            |&(cap, n, seed)| {
                let mut rb = ReplayBuffer::new(cap, seed);
                for i in 0..n {
                    rb.push(sample(i as f32), i % 7);
                }
                if rb.len() != cap.min(n) {
                    return Err(format!("len {} != min(cap {cap}, n {n})", rb.len()));
                }
                if rb.seen() != n as u64 {
                    return Err(format!("seen {} != {n}", rb.seen()));
                }
                for (x, y) in &rb.items {
                    let v = x.data()[0] as usize;
                    if v >= n || *y != v % 7 {
                        return Err(format!("retained item ({v}, {y}) not from the stream"));
                    }
                }
                Ok(())
            },
        );
    }
}
