//! # tinytrain
//!
//! Reproduction of *"On-Device Training of Fully Quantized Deep Neural
//! Networks on Cortex-M Microcontrollers"* (Deutel et al., IEEE TCAD 2024)
//! as a three-layer Rust + JAX + Pallas system:
//!
//!  * **L3 (this crate)** — the on-device training framework: fully
//!    quantized training (FQT, §III-A), dynamic sparse gradient updates
//!    (§III-B), the training coordinator, memory planner, MCU device
//!    models, and synthetic dataset substrates.
//!  * **L2/L1 (`python/compile/`)** — JAX train-step graphs calling Pallas
//!    FQT kernels, AOT-lowered once to HLO text artifacts.
//!  * **runtime** — loads the artifacts via the PJRT C API (`xla` crate)
//!    and executes them from Rust; Python is never on the training path.
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for reproduced numbers.

pub mod coordinator;
pub mod data;
pub mod device;
pub mod graph;
pub mod harness;
pub mod kernels;
pub mod memplan;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
