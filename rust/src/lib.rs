//! # tinytrain
//!
//! Reproduction of *"On-Device Training of Fully Quantized Deep Neural
//! Networks on Cortex-M Microcontrollers"* (Deutel et al., IEEE TCAD 2024)
//! as a three-layer Rust + JAX + Pallas system:
//!
//!  * **L3 (this crate)** — the on-device training framework: fully
//!    quantized training (FQT, §III-A), dynamic sparse gradient updates
//!    (§III-B), the training coordinator, memory planner, MCU device
//!    models, and synthetic dataset substrates.
//!  * **L2/L1 (`python/compile/`)** — JAX train-step graphs calling Pallas
//!    FQT kernels, AOT-lowered once to HLO text artifacts.
//!  * **runtime** — loads the artifacts via the PJRT C API (`xla` crate)
//!    and executes them from Rust; Python is never on the training path.
//!
//! See DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for reproduced numbers (both at the repository root).
//!
//! ## Execution engines
//!
//! Three native execution paths share one numerics contract:
//!
//!  * the **scalar kernels** (`kernels::{qconv, fconv, qlinear, …}`) are
//!    the MCU-faithful reference — the Rust port of what the paper's C
//!    framework executes on a Cortex-M;
//!  * the **depthwise engine** ([`kernels::dwconv`]) runs depthwise
//!    convolutions — the op mix dominating the paper's MCUNet-style
//!    backbones — on register-blocked per-channel tiles (forward, dW and
//!    dX, with whole-channel sparse skipping and plan-cached flipped
//!    weight packs), bit-exact with the scalar kernels;
//!  * the **batched im2col/GEMM engine** (`kernels::gemm`, backed by the
//!    [`memplan::Scratch`] arena) lowers non-depthwise convolutions onto
//!    MR×NR register-blocked integer micro-kernels, caches the dense
//!    backward weight packs in the plan ([`graph::packs`], invalidated by
//!    the optimizers' dirty bits) and shards minibatch samples across a
//!    persistent worker pool ([`graph::batch::WorkerPool`]) via
//!    [`graph::exec::NativeModel::train_batch_pooled`] /
//!    [`train::loop_::train_batched`] (`TT_WORKERS` knob). Integer
//!    accumulation is exact, per-sample work runs against a frozen model
//!    snapshot, and all state updates are merged in sample order — so the
//!    engine is **bit-exact** with the scalar reference and produces
//!    **bit-identical weights for every worker count** (property-tested).
//!
//! Both passes execute through a **compile-once layer-op plan**
//! ([`graph::plan::ExecPlan`]): at deployment the graph is lowered into a
//! `Vec<Box<dyn LayerOp>>` with pre-resolved shapes, precisions and
//! quantization-parameter slots, plus a liveness-planned activation arena
//! (`planned_peak_bytes`) and the exact scratch requirements of a
//! training step — so a step performs zero arena growth after plan
//! construction, `Flatten` is a zero-copy view, and per-sample execution
//! is pure dispatch. The pre-plan straight-line executor is retained in
//! [`graph::reference`] as the golden parity oracle (DESIGN.md §3).
//!
//! ## Cargo features
//!
//!  * `pjrt` (off by default) — compiles the PJRT runtime
//!    (`runtime::Runtime`, `runtime::xla_trainer`) and the XLA
//!    cross-validation suite. Requires the `xla` crate (uncomment it in
//!    `Cargo.toml`); the default build is fully offline and
//!    dependency-free.
//!  * `gpu` (off by default) — compiles the wgpu/WGSL compute backend
//!    (`backend::gpu`): a `GpuPlan` that lowers the compiled
//!    [`graph::plan::ExecPlan`] schedule onto WGSL compute shaders for
//!    batched forward inference, cross-validated bit-for-bit (u8/i32)
//!    and tolerance-tiered (f32) against the native engine. Requires the
//!    `wgpu` crate (uncomment it in `Cargo.toml`). The WGSL shader
//!    sources and their scalar-mirror unit tests ([`backend::wgsl`])
//!    compile in the default build — only the device plumbing is gated.

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod graph;
pub mod harness;
pub mod kernels;
pub mod memplan;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
