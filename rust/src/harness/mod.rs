//! Experiment harness shared by the benches: the full evaluation pipelines
//! of §IV (pretrain → PTQ → deploy → on-device retrain), with environment
//! knobs so recorded runs can trade fidelity for wall-clock:
//!
//!   TT_EPOCHS    on-device training epochs        (default 5; paper: 20/50)
//!   TT_RUNS      independent repetitions          (default 2; paper: 5)
//!   TT_TRAIN_PC  train samples per class          (default 3)
//!   TT_TEST_PC   test samples per class           (default 2)
//!   TT_WORKERS   batch-engine worker threads      (default 1; results are
//!                bit-identical for every value — see `train_batched`)
//!
//! Accuracy runs use each dataset's *reduced* shape; memory/latency/energy
//! come from the memory planner and device cost model at the *paper*
//! shape (DESIGN.md §7).

use crate::data::{DatasetSpec, Domain};
use crate::device::{Cost, DeviceModel};
use crate::graph::exec::{calibrate, FloatParams, NativeModel};
use crate::graph::plan::ExecPlan;
use crate::graph::{models, DnnConfig, ModelDef};
use crate::kernels::OpCounter;
use crate::memplan::{self, MemoryReport};
use crate::train::fqt::FqtSgd;
use crate::train::loop_::{self, Sparsity, Split, TrainReport};
use crate::train::sparse::DynamicSparse;
use crate::util::json::Json;
use crate::util::prng::Pcg32;

/// Scaling knobs — the typed [`crate::config::RunConfig`], re-exported
/// under the name the harness and benches have always used. The `TT_*`
/// environment variables are parsed in exactly one place
/// ([`crate::config::RunConfig::from_env`]).
pub use crate::config::{RunConfig, RunConfig as Knobs};

/// Paper hyperparameters (§IV-A): lr 0.001, batch 48. The reduced-scale
/// simulations use a slightly larger lr to compensate for the much smaller
/// sample budget; batch is scaled to the tiny split.
pub const LR: f32 = 0.01;
pub const BATCH: usize = 8;

/// A deployed transfer-learning scenario: pretrained on the source domain,
/// deployed (PTQ), classification tail reset, target-domain splits ready.
pub struct TlScenario {
    pub model: NativeModel,
    pub train: Split,
    pub test: Split,
}

/// Builder for the per-dataset model: MbedNet with the dataset's class
/// count and (reduced) input shape, tail of 5 trainable layers.
pub fn mbednet_for(spec: &DatasetSpec, shape: &[usize; 3]) -> ModelDef {
    models::mbednet(shape, spec.classes)
}

/// The reduced-size model grid shared by cross-backend parity suites
/// (`tests/gpu_cross_validation.rs` and friends): one plain-conv network,
/// one depthwise-separable MbedNet and one MCUNet-style backbone, all
/// shrunk so a full parity grid stays fast on a software rasterizer.
pub fn parity_models() -> Vec<ModelDef> {
    vec![
        models::mnist_cnn(&[1, 12, 12], 4),
        models::mbednet(&[3, 16, 16], 5),
        models::mcunet5fps(&[3, 32, 32], 4),
    ]
}

/// Pretrain a float model on the source domain. Returns the trained float
/// parameters (the "GPU baseline" stage of §IV-A, run in-harness).
pub fn pretrain(
    def: &ModelDef,
    src: &Domain,
    epochs: usize,
    knobs: &Knobs,
    seed: u64,
) -> (FloatParams, f32) {
    let mut rng = Pcg32::new(seed, 0x11);
    let mut all_trainable = def.clone();
    all_trainable.set_all_trainable();
    let fp = FloatParams::init(&all_trainable, &mut rng);
    let (tr, te) = src.splits(knobs.train_pc, knobs.test_pc, &mut rng);
    let calib = calibrate(&all_trainable, &fp, &tr.xs[..tr.len().min(4)]);
    let mut m = NativeModel::build(all_trainable, DnnConfig::Float32, &fp, &calib);
    let mut opt = FqtSgd::new(&m, LR, BATCH);
    let rep = loop_::train(&mut m, &mut opt, &tr, &te, epochs, &mut Sparsity::Dense, &mut rng);
    (m.to_float_params(), rep.final_test_acc())
}

/// Build the full TL scenario for one (dataset, config) pair.
pub fn tl_scenario(
    spec: &DatasetSpec,
    cfg: DnnConfig,
    fp: &FloatParams,
    src: &Domain,
    knobs: &Knobs,
    seed: u64,
) -> TlScenario {
    let mut rng = Pcg32::new(seed, 0x22);
    let shape = spec.reduced_shape;
    let def = mbednet_for(spec, &shape);
    let tgt = src.shifted(seed ^ 0x7777);
    let (train, test) = tgt.splits(knobs.train_pc, knobs.test_pc, &mut rng);
    // PTQ calibration on target-domain samples (what the device would see)
    let calib = calibrate(&def, fp, &train.xs[..train.len().min(4)]);
    let mut model = NativeModel::build(def, cfg, fp, &calib);
    // §IV-A: reset the last five layers to random values
    model.reset_trainable(&mut rng);
    TlScenario { model, train, test }
}

/// Run one on-device TL training. `lambda_min = 1.0` means dense updates.
pub fn run_tl(scen: &mut TlScenario, lambda_min: f32, knobs: &Knobs, seed: u64) -> TrainReport {
    let mut rng = Pcg32::new(seed, 0x33);
    let mut opt = FqtSgd::new(&scen.model, LR, BATCH);
    let mut sparsity = if lambda_min >= 1.0 {
        Sparsity::Dense
    } else {
        Sparsity::Dynamic(DynamicSparse::new(lambda_min, 1.0))
    };
    loop_::train(
        &mut scen.model,
        &mut opt,
        &scen.train,
        &scen.test,
        knobs.epochs,
        &mut sparsity,
        &mut rng,
    )
}

/// Shared setup for the §IV-D full-training runs: model, optimizer, data
/// splits and the RNG positioned exactly after setup. Both the sequential
/// and the batched entry points consume this, so their runs start from
/// byte-identical state and engine comparisons stay meaningful.
fn full_training_setup(
    spec: &DatasetSpec,
    cfg: DnnConfig,
    knobs: &Knobs,
    seed: u64,
) -> (NativeModel, FqtSgd, Split, Split, Pcg32) {
    let mut rng = Pcg32::new(seed, 0x44);
    let shape = spec.reduced_shape;
    let def = models::mnist_cnn(&shape, spec.classes);
    let dom = Domain::new(spec, shape, seed ^ 0x1234);
    let (tr, te) = dom.splits(knobs.train_pc * 2, knobs.test_pc * 2, &mut rng);
    let fp = FloatParams::init(&def, &mut rng);
    let calib = calibrate(&def, &fp, &tr.xs[..tr.len().min(4)]);
    let m = NativeModel::build(def, cfg, &fp, &calib);
    let opt = FqtSgd::new(&m, LR, BATCH);
    (m, opt, tr, te, rng)
}

/// Full on-device training from a (poorly) pretrained state (§IV-D: the
/// MNIST-pretrained net fully retrained on each MNIST-family stand-in).
pub fn run_full_training(
    spec: &DatasetSpec,
    cfg: DnnConfig,
    knobs: &Knobs,
    seed: u64,
) -> (TrainReport, NativeModel) {
    let (mut m, mut opt, tr, te, mut rng) = full_training_setup(spec, cfg, knobs, seed);
    let rep =
        loop_::train(&mut m, &mut opt, &tr, &te, knobs.epochs, &mut Sparsity::Dense, &mut rng);
    (rep, m)
}

/// Full on-device training through the batched/threaded execution engine
/// (`knobs.workers` threads, dense updates). Bit-identical to itself for
/// every worker count; the sequential reference stays in
/// [`run_full_training`].
pub fn run_full_training_batched(
    spec: &DatasetSpec,
    cfg: DnnConfig,
    knobs: &Knobs,
    seed: u64,
) -> (TrainReport, NativeModel) {
    let (mut m, mut opt, tr, te, mut rng) = full_training_setup(spec, cfg, knobs, seed);
    let rep = loop_::train_batched(
        &mut m,
        &mut opt,
        &tr,
        &te,
        knobs.epochs,
        BATCH,
        knobs.workers,
        &mut rng,
    );
    (rep, m)
}

/// Per-sample fwd/bwd cost of the current model on a device, via the op
/// counters (the "1000 consecutive training steps" instrumentation).
pub fn step_costs(
    model: &mut NativeModel,
    split: &Split,
    device: &DeviceModel,
    lambda_min: f32,
) -> (Cost, Cost) {
    let mut sparsity = if lambda_min >= 1.0 {
        Sparsity::Dense
    } else {
        // Fig. 6d measures the steady-state (late-training) regime where
        // the loss has converged well below its maximum and the update
        // rate sits at λ_min — seed the controller accordingly.
        let mut ctl = DynamicSparse::new(lambda_min, 1.0);
        ctl.seed_max_loss(1e6);
        Sparsity::Dynamic(ctl)
    };
    let (fwd, bwd) = loop_::measure_step_ops(model, split, 8, &mut sparsity);
    (device.cost(&fwd), device.cost(&bwd))
}

/// Memory report at the paper's native shape for a TL deployment.
pub fn tl_memory(spec: &DatasetSpec, cfg: DnnConfig) -> MemoryReport {
    let def = mbednet_for(spec, &spec.paper_shape);
    memplan::plan(&def, cfg, true)
}

/// Memory section of the run-report JSON: the analytic three-segment
/// report (pass the one already computed for the row, e.g. by
/// [`tl_memory`]) plus the compiled plan's arena — `planned_peak_bytes`
/// and the per-buffer `(name, offset, bytes)` placement — so Fig. 5-style
/// memory claims are reproducible from a single recorded run.
pub fn memory_json(def: &ModelDef, cfg: DnnConfig, rep: &MemoryReport) -> Json {
    let plan = ExecPlan::compile(def, cfg);
    let slots: Vec<Json> = plan
        .arena_table()
        .iter()
        .map(|(name, offset, bytes)| {
            Json::obj(vec![
                ("name", Json::str(name)),
                ("offset", Json::Num(*offset as f64)),
                ("bytes", Json::Num(*bytes as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("feature_ram", Json::Num(rep.feature_ram as f64)),
        ("weight_ram", Json::Num(rep.weight_ram as f64)),
        ("flash", Json::Num(rep.flash as f64)),
        ("planned_peak_bytes", Json::Num(rep.planned_peak_bytes as f64)),
        ("arena", Json::Arr(slots)),
    ])
}

/// Mean and std over per-run values.
pub fn mean_std(vals: &[f32]) -> (f32, f32) {
    (crate::util::stats::mean(vals), crate::util::stats::std(vals))
}

/// Aggregate op counters over a model+split at paper scale without running
/// samples: analytic per-layer MACs (used where paper-shape execution would
/// be too slow — latency is MAC-driven in the cost model anyway).
pub fn analytic_fwd_ops(def: &ModelDef, cfg: DnnConfig) -> OpCounter {
    let macs = def.total_fwd_macs();
    let mut ops = OpCounter::new();
    match cfg {
        DnnConfig::Float32 => ops.float_macs = macs,
        _ => ops.int_macs = macs,
    }
    let act_bytes: usize = def.shapes().iter().map(|s| s.iter().product::<usize>()).sum();
    ops.bytes = (def.total_params() + act_bytes) as u64;
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec_by_name;

    #[test]
    fn tl_pipeline_end_to_end_smoke() {
        let knobs =
            Knobs { epochs: 2, runs: 1, train_pc: 2, test_pc: 1, ..Knobs::default() };
        let spec = spec_by_name("cwru").unwrap();
        let shape = [1usize, 1, 128]; // shrunk further for the unit test
        let mut small = spec.clone();
        small.reduced_shape = shape;
        let src = Domain::new(&small, shape, 1);
        let def = mbednet_for(&small, &shape);
        let (fp, _) = pretrain(&def, &src, 2, &knobs, 2);
        let mut scen = tl_scenario(&small, DnnConfig::Uint8, &fp, &src, &knobs, 3);
        let rep = run_tl(&mut scen, 1.0, &knobs, 4);
        assert_eq!(rep.epochs.len(), 2);
        assert!(rep.samples_seen > 0);
        // reset tail means grads flowed; memory report exists at paper shape
        let mem = tl_memory(&small, DnnConfig::Uint8);
        assert!(mem.total_ram() > 0 && mem.flash > 0);
    }

    #[test]
    fn sparse_tl_cheaper_than_dense() {
        let knobs =
            Knobs { epochs: 1, runs: 1, train_pc: 2, test_pc: 1, ..Knobs::default() };
        let mut spec = spec_by_name("cifar10").unwrap();
        spec.reduced_shape = [3, 16, 16];
        let src = Domain::new(&spec, spec.reduced_shape, 5);
        let def = mbednet_for(&spec, &spec.reduced_shape);
        let (fp, _) = pretrain(&def, &src, 1, &knobs, 6);
        let mut dense = tl_scenario(&spec, DnnConfig::Uint8, &fp, &src, &knobs, 7);
        let mut sparse = tl_scenario(&spec, DnnConfig::Uint8, &fp, &src, &knobs, 7);
        let d = run_tl(&mut dense, 1.0, &knobs, 8);
        let s = run_tl(&mut sparse, 0.1, &knobs, 8);
        assert!(s.bwd_ops.total_macs() < d.bwd_ops.total_macs());
    }

    #[test]
    fn batched_full_training_smoke() {
        let mut spec = spec_by_name("fmnist").unwrap();
        spec.reduced_shape = [1, 12, 12];
        let knobs =
            Knobs { epochs: 2, runs: 1, train_pc: 3, test_pc: 2, workers: 2, ..Knobs::default() };
        let (rep, _) = run_full_training_batched(&spec, DnnConfig::Uint8, &knobs, 5);
        assert_eq!(rep.epochs.len(), 2);
        assert!(rep.samples_seen > 0);
        assert!(rep.fwd_ops.total_macs() > 0 && rep.bwd_ops.total_macs() > 0);
    }

    #[test]
    fn memory_json_carries_plan_arena() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let rep = memplan::plan(&def, DnnConfig::Uint8, true);
        let j = memory_json(&def, DnnConfig::Uint8, &rep);
        assert!(j.get("planned_peak_bytes").as_f64().unwrap() > 0.0);
        let arena = j.get("arena").as_arr().unwrap();
        assert!(!arena.is_empty());
        for slot in arena {
            assert!(slot.get("bytes").as_f64().unwrap() > 0.0);
            assert!(slot.get("offset").as_f64().is_some());
            assert!(slot.get("name").as_str().is_some());
        }
    }

    #[test]
    fn analytic_ops_match_config_domain() {
        let def = models::mbednet(&[3, 32, 32], 10);
        let q = analytic_fwd_ops(&def, DnnConfig::Uint8);
        let f = analytic_fwd_ops(&def, DnnConfig::Float32);
        assert!(q.int_macs > 0 && q.float_macs == 0);
        assert_eq!(f.float_macs, q.int_macs);
    }
}
