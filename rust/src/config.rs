//! Typed run configuration for the training/coordinator path.
//!
//! Every scaling knob the harness, the benches and the fleet coordinator
//! consume lives in [`RunConfig`]. The environment (`TT_EPOCHS`,
//! `TT_RUNS`, `TT_TRAIN_PC`, `TT_TEST_PC`, `TT_WORKERS`) is parsed in
//! exactly one place — [`RunConfig::from_env`] — and feeds the same
//! builder any programmatic caller uses, so CLI behavior and in-process
//! construction can never drift apart. `harness::Knobs` is a re-export of
//! this type, so existing call sites keep compiling unchanged.

use crate::kernels::simd::KernelMode;
use crate::quant::subbyte::WBits;
use crate::util::bench::env_usize;

/// Scaling knobs for a training run (the harness) or a fleet run (the
/// multi-tenant coordinator). Construct via [`RunConfig::builder`] or
/// [`RunConfig::from_env`]; a literal works too, since the benches build
/// reduced-scale variants with struct-update syntax.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunConfig {
    /// On-device training epochs (default 5; paper: 20/50).
    pub epochs: usize,
    /// Independent repetitions (default 2; paper: 5).
    pub runs: usize,
    /// Train samples per class (default 3).
    pub train_pc: usize,
    /// Test samples per class (default 2).
    pub test_pc: usize,
    /// Worker threads for the batched execution engine and the fleet
    /// coordinator (1 = sequential; any value yields bit-identical
    /// results by the determinism contract — see `train_batched` and
    /// `coordinator::fleet`).
    pub workers: usize,
    /// Micro-kernel dispatch mode (`TT_KERNEL=auto|scalar|simd`, default
    /// auto): `auto` follows the plan's autotuned per-shape preference,
    /// `scalar` forces the MCU-faithful scalar oracle everywhere, `simd`
    /// forces the vector path wherever the host ISA allows. All three are
    /// bit-identical on the quantized paths (see `kernels::simd`). The
    /// CLI installs this into the process-wide mode at startup
    /// (`kernels::simd::set_mode`).
    pub kernel: KernelMode,
    /// Uniform weight storage width (`TT_WBITS=8|4|2`, default unset):
    /// forces every quantized weighted layer to the packed sub-byte
    /// representation at this width. Unset leaves the plan compiler's
    /// memory-budget pass (or the plain u8 default) in charge. `8` still
    /// selects the *packed* code path — useful as a bit-exactness oracle,
    /// since a packed-8 deployment must match the u8 path exactly.
    pub wbits: Option<WBits>,
    /// Weight-memory byte budget (`TT_WEIGHT_BUDGET`, default unset): the
    /// plan compiler demotes the largest quantized weight tensors to 4-
    /// then 2-bit storage until total weight bytes fit. Ignored when
    /// `wbits` forces a uniform width.
    pub weight_budget: Option<usize>,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            epochs: 5,
            runs: 2,
            train_pc: 3,
            test_pc: 2,
            workers: 1,
            kernel: KernelMode::Auto,
            wbits: None,
            weight_budget: None,
        }
    }
}

impl RunConfig {
    pub fn builder() -> RunConfigBuilder {
        RunConfigBuilder { cfg: RunConfig::default() }
    }

    /// The single environment parse site: read every `TT_*` scaling knob
    /// and feed it through the validated builder.
    pub fn from_env() -> RunConfig {
        RunConfig::builder()
            .epochs(env_usize("TT_EPOCHS", 5))
            .runs(env_usize("TT_RUNS", 2))
            .train_pc(env_usize("TT_TRAIN_PC", 3))
            .test_pc(env_usize("TT_TEST_PC", 2))
            .workers(env_usize("TT_WORKERS", 1))
            .kernel(
                std::env::var("TT_KERNEL")
                    .ok()
                    .and_then(|v| KernelMode::parse(&v))
                    .unwrap_or_default(),
            )
            .wbits(std::env::var("TT_WBITS").ok().and_then(|v| WBits::parse(&v)))
            .weight_budget(
                std::env::var("TT_WEIGHT_BUDGET").ok().and_then(|v| v.trim().parse().ok()),
            )
            .build()
    }
}

/// Builder for [`RunConfig`] with validated defaults ([`build`] clamps
/// `workers` to at least 1, matching the historical `TT_WORKERS`
/// handling).
///
/// [`build`]: RunConfigBuilder::build
#[derive(Clone, Debug)]
pub struct RunConfigBuilder {
    cfg: RunConfig,
}

impl RunConfigBuilder {
    pub fn epochs(mut self, v: usize) -> Self {
        self.cfg.epochs = v;
        self
    }

    pub fn runs(mut self, v: usize) -> Self {
        self.cfg.runs = v;
        self
    }

    pub fn train_pc(mut self, v: usize) -> Self {
        self.cfg.train_pc = v;
        self
    }

    pub fn test_pc(mut self, v: usize) -> Self {
        self.cfg.test_pc = v;
        self
    }

    pub fn workers(mut self, v: usize) -> Self {
        self.cfg.workers = v;
        self
    }

    pub fn kernel(mut self, v: KernelMode) -> Self {
        self.cfg.kernel = v;
        self
    }

    pub fn wbits(mut self, v: Option<WBits>) -> Self {
        self.cfg.wbits = v;
        self
    }

    pub fn weight_budget(mut self, v: Option<usize>) -> Self {
        self.cfg.weight_budget = v;
        self
    }

    pub fn build(self) -> RunConfig {
        let mut cfg = self.cfg;
        cfg.workers = cfg.workers.max(1);
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_applies_defaults_and_overrides() {
        let d = RunConfig::default();
        assert_eq!(
            d,
            RunConfig {
                epochs: 5,
                runs: 2,
                train_pc: 3,
                test_pc: 2,
                workers: 1,
                kernel: KernelMode::Auto,
                wbits: None,
                weight_budget: None
            }
        );
        let c = RunConfig::builder().epochs(9).workers(4).build();
        assert_eq!(c.epochs, 9);
        assert_eq!(c.workers, 4);
        assert_eq!(c.runs, d.runs);
    }

    #[test]
    fn build_clamps_workers_to_at_least_one() {
        let c = RunConfig::builder().workers(0).build();
        assert_eq!(c.workers, 1);
    }

    #[test]
    fn builder_carries_subbyte_knobs() {
        let d = RunConfig::default();
        assert_eq!(d.wbits, None);
        assert_eq!(d.weight_budget, None);
        let c = RunConfig::builder().wbits(Some(WBits::W4)).weight_budget(Some(4096)).build();
        assert_eq!(c.wbits, Some(WBits::W4));
        assert_eq!(c.weight_budget, Some(4096));
        // The env strings accepted by the parse site.
        assert_eq!(WBits::parse("8"), Some(WBits::W8));
        assert_eq!(WBits::parse("4"), Some(WBits::W4));
        assert_eq!(WBits::parse("2"), Some(WBits::W2));
        assert_eq!(WBits::parse("3"), None);
    }
}
