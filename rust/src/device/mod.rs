//! MCU device models — the simulated hardware substrate (DESIGN.md §7).
//!
//! The paper measures latency and energy on three physical boards (Tab. II:
//! RP2040/Cortex-M0+, nrf52840/Cortex-M4, IMXRT1062/Cortex-M7). We replace
//! the boards with analytic cycle + energy models driven by the op counts
//! the native kernels report ([`crate::kernels::OpCounter`]).
//!
//! Cycle model: `cycles = Σ ops·CPI(op class, device) + bytes/bus_width`.
//! CPI factors encode the microarchitectural properties the paper's
//! cross-MCU observations hinge on:
//!
//!  * the M4/M7 have the DSP extension (`SMLAD`: dual 16-bit MAC per cycle;
//!    the paper's framework uses SIMD heavily) — int8 MACs are cheap;
//!  * the M0+ has no SIMD but the RP2040 ships a single-cycle 32×32
//!    multiplier — int MACs cost a short fixed sequence;
//!  * the M4/M7 have an FPU (1-cycle pipelined f32 MAC); the M0+ soft-floats
//!    every f32 op through ~30–50 cycle libm calls — this is why the paper
//!    could only deploy the uint8 configuration on the RP2040 and why the
//!    nrf52840 *outpaces* the higher-clocked RP2040 (Fig. 5a);
//!  * the M7 is dual-issue with a wider bus, giving it an additional IPC
//!    advantage on top of its 600 MHz clock.
//!
//! Energy model: `E = (I_active − I_idle) · V · t` (the paper subtracts the
//! idle draw, Fig. 5b/7b). Active-minus-idle deltas are set to typical
//! datasheet compute-load deltas and produce the paper's ordering: the
//! IMXRT1062 is the most energy-efficient *per sample* (it finishes fast),
//! the nrf52840 the least.

use crate::kernels::OpCounter;

/// Static description of one MCU (Tab. II plus model factors).
#[derive(Clone, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    pub core: &'static str,
    pub clock_hz: f64,
    /// Idle current draw (Tab. II), amps.
    pub idle_a: f64,
    /// Active-minus-idle current under compute load, amps.
    pub active_delta_a: f64,
    /// Supply voltage.
    pub volts: f64,
    pub flash_bytes: usize,
    pub ram_bytes: usize,
    pub has_fpu: bool,
    pub has_dsp_simd: bool,
    /// Cycles per int8 MAC (after SIMD amortization).
    pub cpi_int_mac: f64,
    /// Cycles per f32 MAC.
    pub cpi_float_mac: f64,
    /// Cycles per miscellaneous int op (requant, compare, routing).
    pub cpi_int_op: f64,
    /// Cycles per miscellaneous f32 op.
    pub cpi_float_op: f64,
    /// Bytes moved per cycle through the memory system.
    pub bytes_per_cycle: f64,
}

/// RP2040 (Cortex-M0+, 133 MHz). No FPU, no DSP SIMD; single-cycle 32×32
/// multiplier, so an int8 MAC is a load/extend/mul/add sequence (~4
/// cycles); f32 goes through soft-float (~35 cycles per MAC).
pub fn rp2040() -> DeviceModel {
    DeviceModel {
        name: "RP2040",
        core: "Cortex-M0+",
        clock_hz: 133e6,
        idle_a: 31.24e-3,
        active_delta_a: 6.0e-3,
        volts: 3.3,
        flash_bytes: 16 * 1024 * 1024, // external QSPI flash
        ram_bytes: 264 * 1024,
        has_fpu: false,
        has_dsp_simd: false,
        cpi_int_mac: 4.0,
        cpi_float_mac: 35.0,
        cpi_int_op: 3.0,
        cpi_float_op: 30.0,
        bytes_per_cycle: 2.0,
    }
}

/// nrf52840 (Cortex-M4F, 64 MHz). FPU + DSP extension: `SMLAD` dual-MACs
/// int16 operands (int8 widened on load), pipelined 1-cycle f32 MAC.
pub fn nrf52840() -> DeviceModel {
    DeviceModel {
        name: "nrf52840",
        core: "Cortex-M4",
        clock_hz: 64e6,
        idle_a: 7.27e-3,
        active_delta_a: 16.0e-3,
        volts: 3.3,
        flash_bytes: 1024 * 1024, // internal
        ram_bytes: 256 * 1024,
        has_fpu: true,
        has_dsp_simd: true,
        cpi_int_mac: 0.75, // SMLAD + load amortization
        cpi_float_mac: 1.4,
        cpi_int_op: 1.5,
        cpi_float_op: 2.0,
        bytes_per_cycle: 4.0,
    }
}

/// IMXRT1062 (Cortex-M7, 600 MHz). Dual-issue, DSP + FPU, wide AXI bus,
/// TCM. (The paper labels it IMXRT2062 in places; Tab. II and Fig. 7
/// text use IMXRT1062 — same Teensy-class part.)
pub fn imxrt1062() -> DeviceModel {
    DeviceModel {
        name: "IMXRT1062",
        core: "Cortex-M7",
        clock_hz: 600e6,
        idle_a: 108.26e-3,
        active_delta_a: 90.0e-3,
        volts: 3.3,
        flash_bytes: 16 * 1024 * 1024, // external
        ram_bytes: 2 * 512 * 1024,
        has_fpu: true,
        has_dsp_simd: true,
        cpi_int_mac: 0.4, // SMLAD + dual issue
        cpi_float_mac: 0.7,
        cpi_int_op: 0.8,
        cpi_float_op: 1.0,
        bytes_per_cycle: 8.0,
    }
}

/// All three devices of the evaluation.
pub fn all_devices() -> Vec<DeviceModel> {
    vec![imxrt1062(), nrf52840(), rp2040()]
}

/// Look a device up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<DeviceModel> {
    let n = name.to_lowercase();
    all_devices().into_iter().find(|d| d.name.to_lowercase() == n)
}

/// Result of pricing an op bundle on a device.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cost {
    pub cycles: f64,
    pub seconds: f64,
    /// Joules, idle draw excluded (the paper's reporting convention).
    pub joules: f64,
}

impl DeviceModel {
    /// Price an op-count bundle.
    pub fn cost(&self, ops: &OpCounter) -> Cost {
        let compute = ops.int_macs as f64 * self.cpi_int_mac
            + ops.float_macs as f64 * self.cpi_float_mac
            + ops.int_ops as f64 * self.cpi_int_op
            + ops.float_ops as f64 * self.cpi_float_op;
        let memory = ops.bytes as f64 / self.bytes_per_cycle;
        // compute and memory partially overlap on these in-order cores;
        // model as max + 20% of the smaller term
        let (hi, lo) = if compute >= memory { (compute, memory) } else { (memory, compute) };
        let cycles = hi + 0.2 * lo;
        let seconds = cycles / self.clock_hz;
        let joules = self.active_delta_a * self.volts * seconds;
        Cost { cycles, seconds, joules }
    }

    /// Whether a deployment with the given RAM/Flash footprint fits.
    pub fn fits(&self, ram: usize, flash: usize) -> bool {
        ram <= self.ram_bytes && flash <= self.flash_bytes
    }

    /// Energy including idle draw over a fixed sample period (the paper's
    /// §IV-B observation: with a slow sample arrival rate, the MCU with the
    /// lowest idle power wins even if it computes more slowly).
    pub fn energy_at_rate(&self, ops: &OpCounter, sample_period_s: f64) -> f64 {
        let c = self.cost(ops);
        let busy = c.seconds.min(sample_period_s);
        let idle = (sample_period_s - busy).max(0.0);
        (self.idle_a + self.active_delta_a) * self.volts * busy + self.idle_a * self.volts * idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn int_bundle(macs: u64) -> OpCounter {
        OpCounter { int_macs: macs, bytes: macs / 4, ..Default::default() }
    }

    fn float_bundle(macs: u64) -> OpCounter {
        OpCounter { float_macs: macs, bytes: macs, ..Default::default() }
    }

    #[test]
    fn imxrt_fastest_rp2040_slowest_int8() {
        let ops = int_bundle(1_000_000);
        let t_imx = imxrt1062().cost(&ops).seconds;
        let t_nrf = nrf52840().cost(&ops).seconds;
        let t_rp = rp2040().cost(&ops).seconds;
        assert!(t_imx < t_nrf && t_nrf < t_rp, "{t_imx} {t_nrf} {t_rp}");
    }

    #[test]
    fn nrf_beats_rp2040_despite_lower_clock() {
        // Fig. 5a: SIMD+FPU beat raw clock speed.
        let ops = int_bundle(5_000_000);
        assert!(nrf52840().cost(&ops).seconds < rp2040().cost(&ops).seconds);
        let fops = float_bundle(1_000_000);
        assert!(nrf52840().cost(&fops).seconds < rp2040().cost(&fops).seconds / 5.0);
    }

    #[test]
    fn imxrt_most_energy_efficient_per_sample_nrf_least() {
        // Fig. 5b ordering (idle excluded).
        let ops = int_bundle(5_000_000);
        let e_imx = imxrt1062().cost(&ops).joules;
        let e_nrf = nrf52840().cost(&ops).joules;
        let e_rp = rp2040().cost(&ops).joules;
        assert!(e_imx < e_rp && e_rp < e_nrf, "imx={e_imx} rp={e_rp} nrf={e_nrf}");
    }

    #[test]
    fn idle_dominated_rate_favors_nrf() {
        // §IV-B: at a slow fixed sample rate the lowest-idle MCU wins.
        let ops = int_bundle(1_000_000);
        let period = 1.0; // one sample per second
        let e_imx = imxrt1062().energy_at_rate(&ops, period);
        let e_nrf = nrf52840().energy_at_rate(&ops, period);
        let e_rp = rp2040().energy_at_rate(&ops, period);
        assert!(e_nrf < e_rp && e_nrf < e_imx, "nrf={e_nrf} rp={e_rp} imx={e_imx}");
    }

    #[test]
    fn float_penalty_only_on_m0plus() {
        let iops = int_bundle(1_000_000);
        let fops = float_bundle(1_000_000);
        // RP2040: float ~9x slower than int8
        let ratio_rp = rp2040().cost(&fops).seconds / rp2040().cost(&iops).seconds;
        assert!(ratio_rp > 5.0, "ratio={ratio_rp}");
        // M7: float < 2.5x int8
        let ratio_imx = imxrt1062().cost(&fops).seconds / imxrt1062().cost(&iops).seconds;
        assert!(ratio_imx < 2.5, "ratio={ratio_imx}");
    }

    #[test]
    fn tab2_inventory() {
        let d = by_name("rp2040").unwrap();
        assert_eq!(d.ram_bytes, 264 * 1024);
        assert!(!d.has_fpu);
        let d = by_name("NRF52840").unwrap();
        assert_eq!(d.flash_bytes, 1024 * 1024);
        assert!(by_name("esp32").is_none());
        assert_eq!(all_devices().len(), 3);
    }

    #[test]
    fn fits_checks_both_memories() {
        let d = nrf52840();
        assert!(d.fits(100 * 1024, 500 * 1024));
        assert!(!d.fits(300 * 1024, 500 * 1024)); // RAM too big
        assert!(!d.fits(100 * 1024, 2 * 1024 * 1024)); // flash too big
    }
}
