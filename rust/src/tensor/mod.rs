//! Dense row-major tensors.
//!
//! The on-device framework works with per-sample tensors (no batch
//! dimension — the paper accumulates gradients over successive samples
//! instead of batching activations, §III-A option (b)), so shapes are small:
//! `[C, H, W]` for feature maps, `[Cout, Cin, Kh, Kw]` for conv weights,
//! `[Out, In]` for linear weights.
//!
//! Three element types are used, mirroring the MCU memory layout:
//! `u8` (quantized values), `i32` (accumulators / bias), `f32` (gradient
//! buffers, float-config layers).

/// A dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorU8 = Tensor<u8>;
pub type TensorI32 = Tensor<i32>;
pub type TensorF32 = Tensor<f32>;

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Build from existing data; length must match the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape of identical volume.
    pub fn reshape(&self, shape: &[usize]) -> Tensor<T> {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Number of "structures" along axis 0 (out-channels for conv weights /
    /// errors, rows for linear weights). Used by the sparse-update ranking.
    pub fn outer_dim(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Volume of one outer structure (everything but axis 0).
    pub fn inner_len(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    /// Immutable view of outer structure `i`.
    pub fn outer(&self, i: usize) -> &[T] {
        let inner = self.inner_len();
        &self.data[i * inner..(i + 1) * inner]
    }

    /// Mutable view of outer structure `i`.
    pub fn outer_mut(&mut self, i: usize) -> &mut [T] {
        let inner = self.inner_len();
        &mut self.data[i * inner..(i + 1) * inner]
    }
}

impl Tensor<f32> {
    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> TensorF32 {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }
}

/// 3-D index helper for `[C, H, W]` tensors.
#[inline(always)]
pub fn idx3(c: usize, y: usize, x: usize, h: usize, w: usize) -> usize {
    (c * h + y) * w + x
}

/// 4-D index helper for `[Co, Ci, Kh, Kw]` tensors.
#[inline(always)]
pub fn idx4(a: usize, b: usize, c: usize, d: usize, db: usize, dc: usize, dd: usize) -> usize {
    ((a * db + b) * dc + c) * dd + d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = TensorF32::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_checks_len() {
        TensorU8::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn outer_views() {
        let t = TensorF32::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.outer(0), &[1., 2., 3.]);
        assert_eq!(t.outer(1), &[4., 5., 6.]);
        assert_eq!(t.outer_dim(), 2);
        assert_eq!(t.inner_len(), 3);
    }

    #[test]
    fn idx_helpers_are_row_major() {
        assert_eq!(idx3(1, 2, 3, 4, 5), 1 * 20 + 2 * 5 + 3);
        assert_eq!(idx4(1, 1, 1, 1, 2, 3, 4), 1 * 24 + 1 * 12 + 1 * 4 + 1);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = TensorI32::from_vec(&[4], vec![1, 2, 3, 4]);
        let r = t.reshape(&[2, 2]);
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.data(), &[1, 2, 3, 4]);
    }

    #[test]
    fn scalar_like_outer() {
        let t = TensorF32::zeros(&[5]);
        assert_eq!(t.outer_dim(), 5);
        assert_eq!(t.inner_len(), 1);
    }
}
