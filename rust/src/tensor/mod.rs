//! Dense row-major tensors.
//!
//! The on-device framework works with per-sample tensors (no batch
//! dimension — the paper accumulates gradients over successive samples
//! instead of batching activations, §III-A option (b)), so shapes are small:
//! `[C, H, W]` for feature maps, `[Cout, Cin, Kh, Kw]` for conv weights,
//! `[Out, In]` for linear weights.
//!
//! Three element types are used, mirroring the MCU memory layout:
//! `u8` (quantized values), `i32` (accumulators / bias), `f32` (gradient
//! buffers, float-config layers).
//!
//! Storage is shared copy-on-write (`Arc`-backed): `clone` and
//! [`Tensor::reshape`] are O(1) and alias the same buffer — this is what
//! makes `Flatten` a zero-copy view in the planned executor — while
//! [`Tensor::data_mut`] unshares on first write, so value semantics are
//! preserved exactly.

use std::sync::Arc;

/// A dense row-major tensor with shared copy-on-write storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Arc<Vec<T>>,
}

pub type TensorU8 = Tensor<u8>;
pub type TensorI32 = Tensor<i32>;
pub type TensorF32 = Tensor<f32>;

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new(vec![T::default(); n]) }
    }

    /// Build from existing data; length must match the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data: Arc::new(data) }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new(vec![v; n]) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        self.data.as_slice()
    }

    /// Mutable view of the elements. Unshares the buffer first if it is
    /// aliased by another tensor (copy-on-write), so mutation never
    /// observes or affects an aliasing view.
    pub fn data_mut(&mut self) -> &mut [T] {
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    pub fn into_vec(self) -> Vec<T> {
        Arc::try_unwrap(self.data).unwrap_or_else(|shared| shared.as_ref().clone())
    }

    /// Reinterpret with a new shape of identical volume. Zero-copy: the
    /// returned tensor aliases this tensor's buffer (copy-on-write applies
    /// on the first mutation of either side).
    pub fn reshape(&self, shape: &[usize]) -> Tensor<T> {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: Arc::clone(&self.data) }
    }

    /// Whether two tensors alias the same underlying buffer (used by the
    /// zero-copy regression tests; not a value comparison).
    pub fn shares_data(&self, other: &Tensor<T>) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Number of "structures" along axis 0 (out-channels for conv weights /
    /// errors, rows for linear weights). Used by the sparse-update ranking.
    pub fn outer_dim(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Volume of one outer structure (everything but axis 0).
    pub fn inner_len(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    /// Immutable view of outer structure `i`.
    pub fn outer(&self, i: usize) -> &[T] {
        let inner = self.inner_len();
        &self.data[i * inner..(i + 1) * inner]
    }

    /// Mutable view of outer structure `i` (unshares first, like
    /// [`Tensor::data_mut`]).
    pub fn outer_mut(&mut self, i: usize) -> &mut [T] {
        let inner = self.inner_len();
        &mut Arc::make_mut(&mut self.data)[i * inner..(i + 1) * inner]
    }
}

impl Tensor<f32> {
    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> TensorF32 {
        Tensor {
            shape: self.shape.clone(),
            data: Arc::new(self.data.iter().map(|&x| f(x)).collect()),
        }
    }
}

/// 3-D index helper for `[C, H, W]` tensors.
#[inline(always)]
pub fn idx3(c: usize, y: usize, x: usize, h: usize, w: usize) -> usize {
    (c * h + y) * w + x
}

/// 4-D index helper for `[Co, Ci, Kh, Kw]` tensors.
#[inline(always)]
pub fn idx4(a: usize, b: usize, c: usize, d: usize, db: usize, dc: usize, dd: usize) -> usize {
    ((a * db + b) * dc + c) * dd + d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = TensorF32::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_checks_len() {
        TensorU8::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn outer_views() {
        let t = TensorF32::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.outer(0), &[1., 2., 3.]);
        assert_eq!(t.outer(1), &[4., 5., 6.]);
        assert_eq!(t.outer_dim(), 2);
        assert_eq!(t.inner_len(), 3);
    }

    #[test]
    fn idx_helpers_are_row_major() {
        assert_eq!(idx3(1, 2, 3, 4, 5), 1 * 20 + 2 * 5 + 3);
        assert_eq!(idx4(1, 1, 1, 1, 2, 3, 4), 1 * 24 + 1 * 12 + 1 * 4 + 1);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = TensorI32::from_vec(&[4], vec![1, 2, 3, 4]);
        let r = t.reshape(&[2, 2]);
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.data(), &[1, 2, 3, 4]);
    }

    #[test]
    fn reshape_is_zero_copy() {
        let t = TensorF32::from_vec(&[4], vec![1., 2., 3., 4.]);
        let r = t.reshape(&[2, 2]);
        assert!(r.shares_data(&t), "reshape must alias the source buffer");
        let c = t.clone();
        assert!(c.shares_data(&t), "clone must alias until first mutation");
    }

    #[test]
    fn copy_on_write_preserves_value_semantics() {
        let a = TensorI32::from_vec(&[3], vec![1, 2, 3]);
        let mut b = a.clone();
        b.data_mut()[0] = 99;
        assert_eq!(a.data(), &[1, 2, 3], "source must be unaffected by a clone's mutation");
        assert_eq!(b.data(), &[99, 2, 3]);
        assert!(!b.shares_data(&a), "mutation must unshare the buffer");
    }

    #[test]
    fn scalar_like_outer() {
        let t = TensorF32::zeros(&[5]);
        assert_eq!(t.outer_dim(), 5);
        assert_eq!(t.inner_len(), 1);
    }
}
