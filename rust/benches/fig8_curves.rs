//! Fig. 8 — loss and accuracy curves across gradient update rates
//! (flowers stand-in, mixed configuration): convergence *speed* must be
//! preserved under sparse updates — lower λ_min saves compute without
//! slowing the loss curve.

use tinytrain::data::{spec_by_name, Domain};
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::util::bench::{ResultSink, Table};
use tinytrain::util::json::Json;

fn main() {
    let mut knobs = Knobs::from_env();
    knobs.epochs = knobs.epochs.max(8); // curves need some length
    println!("Fig. 8 reproduction — knobs: {knobs:?}");
    let mut spec = spec_by_name("flowers").unwrap();
    spec.reduced_shape = [3, 24, 24];

    let src = Domain::new(&spec, spec.reduced_shape, 90);
    let def = harness::mbednet_for(&spec, &spec.reduced_shape);
    let (fp, _) = harness::pretrain(&def, &src, knobs.epochs, &knobs, 91);

    let mut tab = Table::new(
        "Fig. 8 — per-epoch train loss / test accuracy (flowers, mixed)",
        &["epoch", "loss λ=1.0", "loss λ=0.5", "loss λ=0.1", "acc λ=1.0", "acc λ=0.5", "acc λ=0.1"],
    );
    let mut sink = ResultSink::new("fig8_curves");
    let mut curves = Vec::new();
    for &lambda in &[1.0f32, 0.5, 0.1] {
        let mut scen = harness::tl_scenario(&spec, DnnConfig::Mixed, &fp, &src, &knobs, 92);
        let rep = harness::run_tl(&mut scen, lambda, &knobs, 93);
        for (i, e) in rep.epochs.iter().enumerate() {
            sink.push(Json::obj(vec![
                ("lambda_min", Json::Num(lambda as f64)),
                ("epoch", Json::Num(i as f64)),
                ("train_loss", Json::Num(e.train_loss as f64)),
                ("test_acc", Json::Num(e.test_acc as f64)),
            ]));
        }
        curves.push(rep);
    }
    for ep in 0..knobs.epochs {
        tab.row(&[
            format!("{ep}"),
            format!("{:.3}", curves[0].epochs[ep].train_loss),
            format!("{:.3}", curves[1].epochs[ep].train_loss),
            format!("{:.3}", curves[2].epochs[ep].train_loss),
            format!("{:.3}", curves[0].epochs[ep].test_acc),
            format!("{:.3}", curves[1].epochs[ep].test_acc),
            format!("{:.3}", curves[2].epochs[ep].test_acc),
        ]);
    }
    tab.print();

    // convergence-speed check: epochs to reach 90% of the dense loss drop
    let drop_epoch = |rep: &tinytrain::train::loop_::TrainReport| -> usize {
        let first = rep.epochs[0].train_loss;
        let last = rep.epochs.last().unwrap().train_loss;
        let target = first - 0.9 * (first - last);
        rep.epochs.iter().position(|e| e.train_loss <= target).unwrap_or(rep.epochs.len())
    };
    println!(
        "\nepochs to 90% of final loss drop: λ=1.0: {}, λ=0.5: {}, λ=0.1: {}",
        drop_epoch(&curves[0]),
        drop_epoch(&curves[1]),
        drop_epoch(&curves[2])
    );
    println!("expected shape: all three curves converge at a similar rate (paper Fig. 8).");
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
