//! Fig. 7 — complete on-device training: (a) accuracy on the four
//! MNIST-family stand-ins × three configurations; (b) latency + energy
//! per training sample for EMNIST-Digits on all three MCUs, with the
//! fwd/bwd split (full training: bwd dominates, the inverse of Fig. 4b).

use tinytrain::data::{full_training_specs, spec_by_name};
use tinytrain::device;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::train::loop_::Split;
use tinytrain::util::bench::{fmt_duration, ResultSink, Table};
use tinytrain::util::json::Json;

fn main() {
    let knobs = Knobs::from_env();
    println!("Fig. 7 reproduction — knobs: {knobs:?} (paper: lr 1e-3, batch 48, 5 runs)");
    let mut acc_tab = Table::new(
        "Fig. 7a — full on-device training accuracy",
        &["dataset", "uint8", "mixed", "float32"],
    );
    let mut sink = ResultSink::new("fig7_full_training");

    for spec in full_training_specs() {
        let mut row = vec![spec.name.to_string()];
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let mut accs = Vec::new();
            for run in 0..knobs.runs {
                let (rep, _) = harness::run_full_training(&spec, cfg, &knobs, 400 + run as u64);
                accs.push(rep.final_test_acc());
            }
            let (m, s) = harness::mean_std(&accs);
            row.push(format!("{m:.3}±{s:.3}"));
            sink.push(Json::obj(vec![
                ("fig", Json::str("7a")),
                ("dataset", Json::str(spec.name)),
                ("config", Json::str(cfg.name())),
                ("acc_mean", Json::Num(m as f64)),
                ("acc_std", Json::Num(s as f64)),
            ]));
        }
        acc_tab.row(&row);
    }
    acc_tab.print();

    // 7b: EMNIST-Digits across devices — fwd/bwd split + energy
    let spec = spec_by_name("emnist-digits").unwrap();
    let mut lat_tab = Table::new(
        "Fig. 7b — EMNIST-Digits latency + energy per training sample",
        &["device", "config", "fwd", "bwd", "bwd/fwd", "energy", "fits"],
    );
    for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
        let (_, mut model) =
            harness::run_full_training(&spec, cfg, &Knobs { epochs: 1, ..knobs }, 7);
        let mut rng = tinytrain::util::prng::Pcg32::seeded(9);
        let dom = tinytrain::data::Domain::new(&spec, spec.reduced_shape, 9);
        let (split, _): (Split, Split) = dom.splits(2, 0, &mut rng);
        let mem = tinytrain::memplan::plan(&model.shared.def.clone(), cfg, true);
        for dev in device::all_devices() {
            let (f, b) = harness::step_costs(&mut model, &split, &dev, 1.0);
            let fits = dev.fits(mem.total_ram(), mem.flash);
            lat_tab.row(&[
                dev.name.into(),
                cfg.name().into(),
                fmt_duration(f.seconds),
                fmt_duration(b.seconds),
                format!("{:.2}", b.seconds / f.seconds),
                format!("{:.3} mJ", (f.joules + b.joules) * 1e3),
                if fits { "yes".into() } else { "NO".into() },
            ]);
            sink.push(Json::obj(vec![
                ("fig", Json::str("7b")),
                ("device", Json::str(dev.name)),
                ("config", Json::str(cfg.name())),
                ("fwd_s", Json::Num(f.seconds)),
                ("bwd_s", Json::Num(b.seconds)),
                ("energy_j", Json::Num(f.joules + b.joules)),
                ("fits", Json::Bool(fits)),
            ]));
        }
    }
    lat_tab.print();
    println!("\nexpected shape: float32 ≥ mixed ≥ uint8 accuracy with a wider gap than");
    println!("transfer learning (features learned from scratch, §IV-D); bwd > fwd per");
    println!("sample (all layers trained); uint8 is the only config fitting nrf52840/RP2040.");
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
