//! Fig. 4 — on-device transfer learning: (a) accuracy per dataset ×
//! {uint8, mixed, float32} + source baseline; (b) per-sample fwd/bwd
//! latency on the IMXRT1062; (c)/(d) RAM and Flash per deployment with
//! the Tab. II constraint check. Scaled by TT_EPOCHS/TT_RUNS/TT_TRAIN_PC.

use tinytrain::data::{transfer_specs, Domain};
use tinytrain::device;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::util::bench::{fmt_duration, ResultSink, Table};
use tinytrain::util::json::Json;

fn main() {
    let knobs = Knobs::from_env();
    println!("Fig. 4 reproduction — knobs: {knobs:?} (paper: 20 epochs, 5 runs, full datasets)");
    let dev = device::imxrt1062();
    let mut acc_tab = Table::new(
        "Fig. 4a — transfer-learning accuracy (mean±std over runs)",
        &["dataset", "baseline", "uint8", "mixed", "float32"],
    );
    let mut lat_tab = Table::new(
        "Fig. 4b — latency per training sample, IMXRT1062 (fwd + bwd)",
        &["dataset", "config", "fwd", "bwd", "total"],
    );
    let mut mem_tab = Table::new(
        "Fig. 4c/4d — memory at paper shapes (uint8/mixed/float32)",
        &["dataset", "config", "feature RAM", "weights+grads RAM", "Flash", "fits"],
    );
    let mut sink = ResultSink::new("fig4_transfer");

    for spec in transfer_specs() {
        let src = Domain::new(&spec, spec.reduced_shape, 100);
        let def = harness::mbednet_for(&spec, &spec.reduced_shape);
        let (fp, baseline) = harness::pretrain(&def, &src, knobs.epochs, &knobs, 101);

        let mut row = vec![spec.name.to_string(), format!("{baseline:.3}")];
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let mut accs = Vec::new();
            for run in 0..knobs.runs {
                let mut scen =
                    harness::tl_scenario(&spec, cfg, &fp, &src, &knobs, 200 + run as u64);
                let rep = harness::run_tl(&mut scen, 1.0, &knobs, 300 + run as u64);
                accs.push(rep.final_test_acc());
                if run == 0 {
                    let (f, b) = harness::step_costs(&mut scen.model, &scen.train, &dev, 1.0);
                    lat_tab.row(&[
                        spec.name.into(),
                        cfg.name().into(),
                        fmt_duration(f.seconds),
                        fmt_duration(b.seconds),
                        fmt_duration(f.seconds + b.seconds),
                    ]);
                    sink.push(Json::obj(vec![
                        ("fig", Json::str("4b")),
                        ("dataset", Json::str(spec.name)),
                        ("config", Json::str(cfg.name())),
                        ("fwd_s", Json::Num(f.seconds)),
                        ("bwd_s", Json::Num(b.seconds)),
                    ]));
                }
            }
            let (m, s) = harness::mean_std(&accs);
            row.push(format!("{m:.3}±{s:.3}"));
            sink.push(Json::obj(vec![
                ("fig", Json::str("4a")),
                ("dataset", Json::str(spec.name)),
                ("config", Json::str(cfg.name())),
                ("baseline", Json::Num(baseline as f64)),
                ("acc_mean", Json::Num(m as f64)),
                ("acc_std", Json::Num(s as f64)),
            ]));

            let mem = harness::tl_memory(&spec, cfg);
            let fits: Vec<String> = device::all_devices()
                .iter()
                .map(|d| {
                    format!(
                        "{}:{}",
                        &d.name[..3],
                        if d.fits(mem.total_ram(), mem.flash) { "y" } else { "N" }
                    )
                })
                .collect();
            mem_tab.row(&[
                spec.name.into(),
                cfg.name().into(),
                format!("{} B", mem.feature_ram),
                format!("{} B", mem.weight_ram),
                format!("{} B", mem.flash),
                fits.join(" "),
            ]);
            // memory section: analytic segments + the compiled plan's
            // arena (planned_peak_bytes, per-buffer offsets) at paper shape
            let def_paper = harness::mbednet_for(&spec, &spec.paper_shape);
            sink.push(Json::obj(vec![
                ("fig", Json::str("4cd")),
                ("dataset", Json::str(spec.name)),
                ("config", Json::str(cfg.name())),
                ("feature_ram", Json::Num(mem.feature_ram as f64)),
                ("weight_ram", Json::Num(mem.weight_ram as f64)),
                ("flash", Json::Num(mem.flash as f64)),
                ("memory", harness::memory_json(&def_paper, cfg, &mem)),
            ]));
        }
        acc_tab.row(&row);
    }
    acc_tab.print();
    lat_tab.print();
    mem_tab.print();
    let p = sink.flush().expect("write results");
    println!("\nresults -> {}", p.display());
}
