//! Tab. IV + Fig. 9 — MCUNet comparison.
//!
//! Tab. IV: retrain the last two blocks of the MCUNet-5FPS stand-in on
//! the eight TL datasets under four optimizers: fp32 SGD-M, naive int8
//! SGD-M, SGD+M+QAS (Lin et al.), and ours (FQT + standardized gradients
//! + dynamic range adaptation). Expected shape: ours ≈ QAS ≈ fp32 ≫
//! naive int8.
//!
//! Fig. 9: memory + per-sample latency of MbedNet vs MCUNet on cifar10 at
//! paper shapes on the IMXRT1062 (paper: MbedNet −34.8 % memory, −49.0 %
//! latency).

use tinytrain::data::{mcunet_specs, spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::exec::{calibrate, NativeModel};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::harness::{self, Knobs};
use tinytrain::memplan;
use tinytrain::train::loop_::{self, Sparsity};
use tinytrain::train::optim::{NaiveQSgdM, QasSgdM, SgdM};
use tinytrain::train::Optimizer;
use tinytrain::util::bench::{fmt_duration, ResultSink, Table};
use tinytrain::util::json::Json;
use tinytrain::util::prng::Pcg32;

fn mcunet_scenario(
    spec: &tinytrain::data::DatasetSpec,
    cfg: DnnConfig,
    fp: &tinytrain::graph::exec::FloatParams,
    src: &Domain,
    knobs: &Knobs,
    seed: u64,
) -> harness::TlScenario {
    let mut rng = Pcg32::new(seed, 0x99);
    let def = models::mcunet5fps(&spec.reduced_shape, spec.classes);
    let tgt = src.shifted(seed ^ 0x5555);
    let (train, test) = tgt.splits(knobs.train_pc, knobs.test_pc, &mut rng);
    let calib = calibrate(&def, fp, &train.xs[..train.len().min(4)]);
    let mut model = NativeModel::build(def, cfg, fp, &calib);
    model.reset_trainable(&mut rng);
    harness::TlScenario { model, train, test }
}

fn run_with(
    scen: &mut harness::TlScenario,
    opt: &mut dyn Optimizer,
    knobs: &Knobs,
    seed: u64,
) -> f32 {
    let mut rng = Pcg32::new(seed, 0xAB);
    let rep = loop_::train(
        &mut scen.model,
        opt,
        &scen.train,
        &scen.test,
        knobs.epochs,
        &mut Sparsity::Dense,
        &mut rng,
    );
    rep.final_test_acc()
}

fn main() {
    let knobs = Knobs::from_env();
    println!("Tab. IV + Fig. 9 reproduction — knobs: {knobs:?} (paper: 50 epochs, lr 1e-3, b 48)");
    let mut tab = Table::new(
        "Tab. IV — optimizer comparison, MCUNet-5FPS stand-in (last two blocks)",
        &[
            "optimizer",
            "precision",
            "cars",
            "cf10",
            "cf100",
            "cub",
            "flowers",
            "food",
            "pets",
            "vww",
            "avg",
        ],
    );
    let mut sink = ResultSink::new("fig9_tab4_mcunet");

    // pretrain once per dataset (float), share across optimizer rows
    let mut pretrained = Vec::new();
    for spec in mcunet_specs() {
        let src = Domain::new(&spec, spec.reduced_shape, 500);
        let def = models::mcunet5fps(&spec.reduced_shape, spec.classes);
        let (fp, _) = harness::pretrain(&def, &src, knobs.epochs, &knobs, 501);
        pretrained.push((spec, src, fp));
    }

    type OptRow = (&'static str, &'static str, DnnConfig, u8);
    let rows: [OptRow; 4] = [
        ("SGD-M", "fp32", DnnConfig::Float32, 0),
        ("SGD-M (naive)", "int8", DnnConfig::Uint8, 1),
        ("SGD+M+QAS", "int8", DnnConfig::Uint8, 2),
        ("ours (FQT)", "uint8", DnnConfig::Uint8, 3),
    ];
    for (name, prec, cfg, kind) in rows {
        let mut cells = vec![name.to_string(), prec.to_string()];
        let mut accs = Vec::new();
        for (spec, src, fp) in &pretrained {
            let mut scen = mcunet_scenario(spec, cfg, fp, src, &knobs, 600);
            let acc = match kind {
                0 => {
                    let mut opt = SgdM::new(&scen.model, harness::LR, harness::BATCH);
                    run_with(&mut scen, &mut opt, &knobs, 601)
                }
                1 => {
                    let mut opt = NaiveQSgdM::new(&scen.model, harness::LR, harness::BATCH);
                    run_with(&mut scen, &mut opt, &knobs, 601)
                }
                2 => {
                    let mut opt = QasSgdM::new(&scen.model, harness::LR, harness::BATCH);
                    run_with(&mut scen, &mut opt, &knobs, 601)
                }
                _ => {
                    let rep = harness::run_tl(&mut scen, 1.0, &knobs, 601);
                    rep.final_test_acc()
                }
            };
            accs.push(acc);
            cells.push(format!("{:.1}", acc * 100.0));
            sink.push(Json::obj(vec![
                ("table", Json::str("IV")),
                ("optimizer", Json::str(name)),
                ("dataset", Json::str(spec.name)),
                ("acc", Json::Num(acc as f64)),
            ]));
        }
        let (m, _) = harness::mean_std(&accs);
        cells.push(format!("{:.1}", m * 100.0));
        tab.row(&cells);
    }
    tab.print();
    println!("paper Tab. IV avgs: fp32 SGD-M 73.3, int8 SGD-M 64.9, SGD+M+QAS 73.5, ours 73.7");

    // ---- Fig. 9: MbedNet vs MCUNet on cifar10, paper shapes ----
    let dev = device::imxrt1062();
    let spec10 = spec_by_name("cf10").unwrap();
    let mut f9 = Table::new(
        "Fig. 9 — MbedNet vs MCUNet (cifar10, IMXRT1062, paper shapes)",
        &["model", "RAM (train)", "Flash", "fwd/sample", "bwd/sample", "total"],
    );
    let mut totals = Vec::new();
    for (mname, def) in [
        ("mbednet", models::mbednet(&[3, 32, 32], 10)),
        ("mcunet5fps", models::mcunet5fps(&spec10.paper_shape, 10)),
    ] {
        let mem = memplan::plan(&def, DnnConfig::Uint8, true);
        // analytic op pricing at paper shape (fwd); bwd ≈ 2x tail MACs
        let fwd_ops = harness::analytic_fwd_ops(&def, DnnConfig::Uint8);
        let tail_macs: u64 = def
            .fwd_macs_per_layer()
            .iter()
            .zip(&def.layers)
            .filter(|(_, l)| l.trainable)
            .map(|(m, _)| *m)
            .sum();
        let mut bwd_ops = tinytrain::kernels::OpCounter::new();
        bwd_ops.int_macs = 2 * tail_macs;
        bwd_ops.bytes = fwd_ops.bytes / 2;
        let f = dev.cost(&fwd_ops);
        let b = dev.cost(&bwd_ops);
        totals.push((mem.total_ram() + mem.flash, f.seconds + b.seconds));
        f9.row(&[
            mname.into(),
            format!("{} B", mem.total_ram()),
            format!("{} B", mem.flash),
            fmt_duration(f.seconds),
            fmt_duration(b.seconds),
            fmt_duration(f.seconds + b.seconds),
        ]);
        sink.push(Json::obj(vec![
            ("fig", Json::str("9")),
            ("model", Json::str(mname)),
            ("ram", Json::Num(mem.total_ram() as f64)),
            ("flash", Json::Num(mem.flash as f64)),
            ("fwd_s", Json::Num(f.seconds)),
            ("bwd_s", Json::Num(b.seconds)),
        ]));
    }
    f9.print();
    let mem_save = 100.0 * (1.0 - totals[0].0 as f64 / totals[1].0 as f64);
    let lat_save = 100.0 * (1.0 - totals[0].1 / totals[1].1);
    println!(
        "\nMbedNet vs MCUNet: {:.1}% less memory, {:.1}% lower latency (paper: 34.8% / 49.0%)",
        mem_save, lat_save
    );
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
