//! §Perf microbenchmarks — wall-clock throughput of the native kernels
//! (the simulated-MCU hot path), the im2col/GEMM execution engine, and the
//! PJRT-executed artifact (with `--features pjrt`), plus GPU-vs-native
//! forward rows (with `--features gpu` and a usable adapter). Used by the
//! performance pass; before/after numbers live in EXPERIMENTS.md §Perf.
//!
//! Knobs: TT_PERF_REPS (default 10), TT_PERF_BATCH (default 8),
//! TT_WORKERS (default: one per available core, capped at the batch).

use std::sync::Arc;

use tinytrain::config::RunConfig;
use tinytrain::coordinator::fleet::{FleetConfig, FleetCoordinator};
use tinytrain::coordinator::CoordinatorConfig;
use tinytrain::data::{spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::exec::{calibrate, DenseUpdates, FloatParams, ModelArtifacts, NativeModel};
use tinytrain::graph::plan::{BitSpec, ExecPlan};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::kernels::simd::{self, KernelSel};
use tinytrain::kernels::{dwconv, fconv, gemm, qconv, qlinear, softmax, ConvGeom, OpCounter};
use tinytrain::memplan::Scratch;
use tinytrain::quant::subbyte::{pack_lanes, WBits};
use tinytrain::quant::{requantize, QParams, QTensor};
use tinytrain::tensor::TensorF32;
use tinytrain::train::fqt::FqtSgd;
use tinytrain::train::Optimizer;
use tinytrain::util::bench::{
    check_perf_rows, env_usize, fmt_duration, safe_speedup, time_it, ResultSink, Table,
};
use tinytrain::util::json::Json;
use tinytrain::util::prng::Pcg32;

fn rand_q(rng: &mut Pcg32, shape: &[usize]) -> QTensor {
    let mut t = TensorF32::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    QTensor::quantize(&t)
}

fn main() {
    let reps = env_usize("TT_PERF_REPS", 10);
    let batch = env_usize("TT_PERF_BATCH", 8).max(1);
    let default_workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let workers = env_usize("TT_WORKERS", default_workers).clamp(1, batch);
    let mut rng = Pcg32::seeded(1);
    let mut tab = Table::new(
        "§Perf — native kernel throughput",
        &["kernel", "shape", "time", "GMAC/s"],
    );
    let mut sink = ResultSink::new("perf_kernels");

    // conv fwd: the mbednet stem-like layer (dominates TL forward cost)
    let g = ConvGeom {
        cin: 16,
        cout: 32,
        kh: 3,
        kw: 3,
        stride: 1,
        pad_h: 1,
        pad_w: 1,
        depthwise: false,
    };
    let x = rand_q(&mut rng, &[16, 32, 32]);
    let w = rand_q(&mut rng, &[32, 16, 3, 3]);
    let bias = vec![0i32; 32];
    let oqp = QParams::from_min_max(0.0, 4.0);
    let macs = g.fwd_macs(32, 32) as f64;
    let (t, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(qconv::qconv2d_fwd(&x, &w, &bias, &g, oqp, true, &mut ops));
    });
    tab.row(&[
        "qconv2d_fwd scalar".into(),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(t),
        format!("{:.2}", macs / t / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("qconv2d_fwd")),
        ("seconds", Json::Num(t)),
        ("gmacs", Json::Num(macs / t / 1e9)),
    ]));

    // the same layer through the im2col/GEMM engine
    let mut scratch = Scratch::new();
    let (tg, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(qconv::qconv2d_fwd_gemm(
            &x,
            &w,
            &bias,
            &g,
            oqp,
            true,
            &mut scratch,
            &mut ops,
        ));
    });
    tab.row(&[
        "qconv2d_fwd gemm".into(),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(tg),
        format!("{:.2}", macs / tg / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("qconv2d_fwd_gemm")),
        ("seconds", Json::Num(tg)),
        ("gmacs", Json::Num(macs / tg / 1e9)),
        ("speedup_vs_scalar", Json::Num(t / tg)),
    ]));

    // batched forward, batch >= 8: scalar loop vs GEMM vs GEMM + threads
    let xs: Vec<QTensor> = (0..batch).map(|_| rand_q(&mut rng, &[16, 32, 32])).collect();
    let bmacs = macs * batch as f64;
    let (tb_scalar, _) = time_it(1, reps, || {
        let mut ops = OpCounter::new();
        for xb in &xs {
            std::hint::black_box(qconv::qconv2d_fwd(xb, &w, &bias, &g, oqp, true, &mut ops));
        }
    });
    let (tb_gemm, _) = time_it(1, reps, || {
        let mut ops = OpCounter::new();
        for xb in &xs {
            std::hint::black_box(qconv::qconv2d_fwd_gemm(
                xb,
                &w,
                &bias,
                &g,
                oqp,
                true,
                &mut scratch,
                &mut ops,
            ));
        }
    });
    let (tb_mt, _) = time_it(1, reps, || {
        let chunk = xs.len().div_ceil(workers);
        std::thread::scope(|s| {
            for shard in xs.chunks(chunk) {
                let (w, bias, g) = (&w, &bias, &g);
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    let mut ops = OpCounter::new();
                    for xb in shard {
                        std::hint::black_box(qconv::qconv2d_fwd_gemm(
                            xb,
                            w,
                            bias,
                            g,
                            oqp,
                            true,
                            &mut scratch,
                            &mut ops,
                        ));
                    }
                });
            }
        });
    });
    tab.row(&[
        format!("qconv fwd batch={batch} scalar"),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(tb_scalar),
        format!("{:.2}", bmacs / tb_scalar / 1e9),
    ]);
    tab.row(&[
        format!("qconv fwd batch={batch} gemm"),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(tb_gemm),
        format!("{:.2}", bmacs / tb_gemm / 1e9),
    ]);
    tab.row(&[
        format!("qconv fwd batch={batch} gemm x{workers} thr"),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(tb_mt),
        format!("{:.2}", bmacs / tb_mt / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("qconv2d_fwd_batched")),
        ("batch", Json::Num(batch as f64)),
        ("workers", Json::Num(workers as f64)),
        ("scalar_seconds", Json::Num(tb_scalar)),
        ("gemm_seconds", Json::Num(tb_gemm)),
        ("gemm_mt_seconds", Json::Num(tb_mt)),
        ("gemm_speedup", Json::Num(tb_scalar / tb_gemm)),
        ("gemm_mt_speedup", Json::Num(tb_scalar / tb_mt)),
    ]));
    println!(
        "\nbatched conv fwd (batch {batch}): GEMM {:.2}x, GEMM+{workers} threads {:.2}x vs scalar",
        tb_scalar / tb_gemm,
        tb_scalar / tb_mt
    );

    // float conv fwd: scalar vs GEMM (the float32/mixed configurations)
    let mut xf = TensorF32::zeros(&[16, 32, 32]);
    rng.fill_normal(xf.data_mut(), 1.0);
    let mut wf = TensorF32::zeros(&[32, 16, 3, 3]);
    rng.fill_normal(wf.data_mut(), 0.3);
    let bf = vec![0f32; 32];
    let (tf_scalar, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(fconv::fconv2d_fwd(&xf, &wf, &bf, &g, true, &mut ops));
    });
    let (tf_gemm, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(fconv::fconv2d_fwd_gemm(
            &xf,
            &wf,
            &bf,
            &g,
            true,
            &mut scratch,
            &mut ops,
        ));
    });
    tab.row(&[
        "fconv2d_fwd scalar".into(),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(tf_scalar),
        format!("{:.2}", macs / tf_scalar / 1e9),
    ]);
    tab.row(&[
        "fconv2d_fwd gemm".into(),
        "16x32x32 -> 32, k3".into(),
        fmt_duration(tf_gemm),
        format!("{:.2}", macs / tf_gemm / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("fconv2d_fwd_gemm")),
        ("seconds", Json::Num(tf_gemm)),
        ("speedup_vs_scalar", Json::Num(tf_scalar / tf_gemm)),
    ]));

    // pointwise conv (1x1) — the mbednet/mcunet majority op
    let gp = ConvGeom {
        cin: 64,
        cout: 128,
        kh: 1,
        kw: 1,
        stride: 1,
        pad_h: 0,
        pad_w: 0,
        depthwise: false,
    };
    let xp = rand_q(&mut rng, &[64, 16, 16]);
    let wp = rand_q(&mut rng, &[128, 64, 1, 1]);
    let biasp = vec![0i32; 128];
    let macsp = gp.fwd_macs(16, 16) as f64;
    let (tp, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(qconv::qconv2d_fwd_gemm(
            &xp,
            &wp,
            &biasp,
            &gp,
            oqp,
            true,
            &mut scratch,
            &mut ops,
        ));
    });
    tab.row(&[
        "qconv2d_fwd 1x1 gemm".into(),
        "64x16x16 -> 128".into(),
        fmt_duration(tp),
        format!("{:.2}", macsp / tp / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("qconv2d_fwd_1x1")),
        ("seconds", Json::Num(tp)),
        ("gmacs", Json::Num(macsp / tp / 1e9)),
    ]));

    // conv backward, scalar vs GEMM, at several §III-B sparsity levels:
    // the Eq. 9 controller's kept ratio maps onto whole skipped GEMM rows,
    // so backward time should scale ~linearly with the kept fraction.
    let e = rand_q(&mut rng, &[32, 32, 32]);
    for &kept_frac in &[1.0f64, 0.5, 0.25] {
        let kept_n = ((g.cout as f64 * kept_frac).round() as usize).clamp(1, g.cout);
        // evenly spread the kept channels across the channel range
        let mask: Vec<bool> = {
            let mut m = vec![false; g.cout];
            for j in 0..kept_n {
                m[j * g.cout / kept_n] = true;
            }
            m
        };
        let keep = if kept_frac >= 1.0 { None } else { Some(&mask[..]) };
        let kmacs = macs * kept_frac;
        let label = format!("kept={:.0}%", kept_frac * 100.0);

        let (tbi_s, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(qconv::qconv2d_bwd_input(&e, &w, &g, 32, 32, oqp, keep, &mut ops));
        });
        let (tbi_g, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(qconv::qconv2d_bwd_input_gemm(
                &e,
                &w,
                &g,
                32,
                32,
                oqp,
                keep,
                &mut scratch,
                &mut ops,
            ));
        });
        let (tbw_s, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(qconv::qconv2d_bwd_weight(&e, &x, &g, keep, &mut ops));
        });
        let (tbw_g, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(qconv::qconv2d_bwd_weight_gemm(
                &e,
                &x,
                &g,
                keep,
                &mut scratch,
                &mut ops,
            ));
        });
        tab.row(&[
            format!("qconv bwd_input scalar {label}"),
            "32x32x32".into(),
            fmt_duration(tbi_s),
            format!("{:.2}", kmacs / tbi_s / 1e9),
        ]);
        tab.row(&[
            format!("qconv bwd_input gemm {label}"),
            "32x32x32".into(),
            fmt_duration(tbi_g),
            format!("{:.2}", kmacs / tbi_g / 1e9),
        ]);
        tab.row(&[
            format!("qconv bwd_weight scalar {label}"),
            "32x32x32".into(),
            fmt_duration(tbw_s),
            format!("{:.2}", kmacs / tbw_s / 1e9),
        ]);
        tab.row(&[
            format!("qconv bwd_weight gemm {label}"),
            "32x32x32".into(),
            fmt_duration(tbw_g),
            format!("{:.2}", kmacs / tbw_g / 1e9),
        ]);
        sink.push(Json::obj(vec![
            ("kernel", Json::str("qconv2d_bwd_sparsity")),
            ("kept_fraction", Json::Num(kept_frac)),
            ("bwd_input_scalar_seconds", Json::Num(tbi_s)),
            ("bwd_input_gemm_seconds", Json::Num(tbi_g)),
            ("bwd_input_gemm_speedup", Json::Num(tbi_s / tbi_g)),
            ("bwd_weight_scalar_seconds", Json::Num(tbw_s)),
            ("bwd_weight_gemm_seconds", Json::Num(tbw_g)),
            ("bwd_weight_gemm_speedup", Json::Num(tbw_s / tbw_g)),
        ]));
        println!(
            "conv bwd {label}: input gemm {:.2}x, weight gemm {:.2}x vs scalar",
            tbi_s / tbi_g,
            tbw_s / tbw_g
        );
    }

    // float conv backward, scalar vs GEMM (dense)
    let ef = {
        let mut t = TensorF32::zeros(&[32, 32, 32]);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    };
    let (tfb_s, _) = time_it(1, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(fconv::fconv2d_bwd_input(&ef, &wf, &g, 32, 32, None, &mut ops));
        std::hint::black_box(fconv::fconv2d_bwd_weight(&ef, &xf, &g, None, &mut ops));
    });
    let (tfb_g, _) = time_it(1, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(fconv::fconv2d_bwd_input_gemm(
            &ef,
            &wf,
            &g,
            32,
            32,
            None,
            &mut scratch,
            &mut ops,
        ));
        std::hint::black_box(fconv::fconv2d_bwd_weight_gemm(
            &ef,
            &xf,
            &g,
            None,
            &mut scratch,
            &mut ops,
        ));
    });
    tab.row(&[
        "fconv bwd (in+wt) scalar".into(),
        "32x32x32".into(),
        fmt_duration(tfb_s),
        format!("{:.2}", 2.0 * macs / tfb_s / 1e9),
    ]);
    tab.row(&[
        "fconv bwd (in+wt) gemm".into(),
        "32x32x32".into(),
        fmt_duration(tfb_g),
        format!("{:.2}", 2.0 * macs / tfb_g / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("fconv2d_bwd_gemm")),
        ("scalar_seconds", Json::Num(tfb_s)),
        ("gemm_seconds", Json::Num(tfb_g)),
        ("speedup_vs_scalar", Json::Num(tfb_s / tfb_g)),
    ]));

    // linear fwd (head-sized)
    let xl = rand_q(&mut rng, &[512]);
    let wl = rand_q(&mut rng, &[256, 512]);
    let biasl = vec![0i32; 256];
    let macsl = (512 * 256) as f64;
    let (tl, _) = time_it(2, reps * 4, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(qlinear::qlinear_fwd(&xl, &wl, &biasl, oqp, false, &mut ops));
    });
    tab.row(&[
        "qlinear_fwd".into(),
        "512 -> 256".into(),
        fmt_duration(tl),
        format!("{:.2}", macsl / tl / 1e9),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("qlinear_fwd")),
        ("seconds", Json::Num(tl)),
        ("gmacs", Json::Num(macsl / tl / 1e9)),
    ]));

    // Execution-plan build overhead: compiling the layer-op plan must be
    // O(layers) — a one-off deployment cost, orders of magnitude below a
    // single forward pass, never per-sample. The quick-mode CI smoke
    // records it so a regression (e.g. an accidental per-sample recompile
    // or superlinear liveness pass) shows up in the JSON trajectory.
    for (mname, def) in [
        ("mnist_cnn", models::mnist_cnn(&[1, 28, 28], 10)),
        ("mbednet", models::mbednet(&[3, 32, 32], 10)),
        ("mcunet5fps", models::mcunet5fps(&[3, 32, 32], 10)),
    ] {
        let (tplan, _) = time_it(2, reps.max(10), || {
            std::hint::black_box(ExecPlan::compile(&def, DnnConfig::Uint8));
        });
        let layers = def.layers.len();
        tab.row(&[
            format!("plan_build {mname}"),
            format!("{layers} layers"),
            fmt_duration(tplan),
            String::new(),
        ]);
        sink.push(Json::obj(vec![
            ("kernel", Json::str("plan_build")),
            ("model", Json::str(mname)),
            ("layers", Json::Num(layers as f64)),
            ("seconds", Json::Num(tplan)),
            ("us_per_layer", Json::Num(tplan * 1e6 / layers as f64)),
        ]));
    }

    // §Tentpole: MR×NR register-blocked micro-kernel vs the retained
    // PR 2/3 cache-blocked path, on MCUNet-style conv-as-GEMM shapes
    // (m = Cout, k = Cin·Kh·Kw, n = Oh·Ow). Both paths are bit-exact with
    // each other, so this isolates the schedule change; the acceptance
    // bar is micro ≥ tiled on every row.
    let mut micro_rows: Vec<Json> = Vec::new();
    for &(label, mm, kdim, nsp) in &[
        ("stem3x3 16x27x1024", 16usize, 27usize, 1024usize),
        ("blk3x3 32x144x256", 32, 144, 256),
        ("pw 96x16x256", 96, 16, 256),
        ("pw 24x96x256", 24, 96, 256),
        ("head1x1 128x64x64", 128, 64, 64),
    ] {
        let a: Vec<u8> = (0..mm * kdim).map(|_| rng.below(256) as u8).collect();
        let bm: Vec<u8> = (0..kdim * nsp).map(|_| rng.below(256) as u8).collect();
        let init = vec![0i32; mm];
        let mut out = vec![0i32; mm * nsp];
        let gmacs = (mm * kdim * nsp) as f64;
        let (tm, _) = time_it(2, reps, || {
            gemm::gemm_u8_i32(&a, 3, &bm, 5, &init, mm, kdim, nsp, &mut out);
            std::hint::black_box(&out);
        });
        let (tt, _) = time_it(2, reps, || {
            gemm::gemm_u8_i32_tiled(&a, 3, &bm, 5, &init, mm, kdim, nsp, &mut out);
            std::hint::black_box(&out);
        });
        tab.row(&[
            "gemm micro".into(),
            label.into(),
            fmt_duration(tm),
            format!("{:.2}", gmacs / tm / 1e9),
        ]);
        tab.row(&[
            "gemm tiled (PR2/3)".into(),
            label.into(),
            fmt_duration(tt),
            format!("{:.2}", gmacs / tt / 1e9),
        ]);
        let row = Json::obj(vec![
            ("kernel", Json::str("gemm_micro_vs_tiled")),
            ("shape", Json::str(label)),
            ("micro_seconds", Json::Num(tm)),
            ("tiled_seconds", Json::Num(tt)),
            ("micro_gmacs", Json::Num(gmacs / tm / 1e9)),
            ("tiled_gmacs", Json::Num(gmacs / tt / 1e9)),
            ("micro_speedup_vs_tiled", Json::Num(tt / tm)),
        ]);
        micro_rows.push(row.clone());
        sink.push(row);
        println!("gemm {label}: micro {:.2}x vs tiled", tt / tm);
    }

    // §Tentpole (PR 6): the fused quantized epilogue vs the retained
    // two-pass sequence (micro-kernel GEMM into an m·n i32 strip, then a
    // separate requantization sweep over it), on the same MCUNet
    // conv-as-GEMM shapes. Both paths are bit-exact, so the delta is
    // purely the skipped i32 round-trip through memory; `bench_gate`
    // holds the geometric mean of `fused_speedup_vs_unfused` over these
    // rows above a machine-independent floor (TT_BENCH_GATE_FUSED_FLOOR).
    let mut fused_rows: Vec<Json> = Vec::new();
    let epi = gemm::QEpilogue { mult: 0.01375, qp: oqp, relu: true };
    for &(label, mm, kdim, nsp) in &[
        ("stem3x3 16x27x1024", 16usize, 27usize, 1024usize),
        ("blk3x3 32x144x256", 32, 144, 256),
        ("pw 96x16x256", 96, 16, 256),
        ("pw 24x96x256", 24, 96, 256),
        ("head1x1 128x64x64", 128, 64, 64),
    ] {
        let a: Vec<u8> = (0..mm * kdim).map(|_| rng.below(256) as u8).collect();
        let bm: Vec<u8> = (0..kdim * nsp).map(|_| rng.below(256) as u8).collect();
        let init = vec![0i32; mm];
        let mut acc = vec![0i32; mm * nsp];
        let mut outq = vec![0u8; mm * nsp];
        let gmacs = (mm * kdim * nsp) as f64;
        let (tu, _) = time_it(2, reps, || {
            gemm::gemm_u8_i32(&a, 3, &bm, 5, &init, mm, kdim, nsp, &mut acc);
            for (q, &v) in outq.iter_mut().zip(acc.iter()) {
                *q = requantize(v, epi.mult, epi.qp.zero_point, epi.relu);
            }
            std::hint::black_box(&outq);
        });
        let (tf, _) = time_it(2, reps, || {
            std::hint::black_box(gemm::gemm_u8_i32_fused(
                &a, 3, &bm, 5, &init, mm, kdim, nsp, &epi, &mut outq, None,
            ));
            std::hint::black_box(&outq);
        });
        tab.row(&[
            "gemm fused epilogue".into(),
            label.into(),
            fmt_duration(tf),
            format!("{:.2}", gmacs / tf / 1e9),
        ]);
        tab.row(&[
            "gemm + requant pass".into(),
            label.into(),
            fmt_duration(tu),
            format!("{:.2}", gmacs / tu / 1e9),
        ]);
        let row = Json::obj(vec![
            ("kernel", Json::str("gemm_fused_epilogue")),
            ("shape", Json::str(label)),
            ("fused_seconds", Json::Num(tf)),
            ("unfused_seconds", Json::Num(tu)),
            ("fused_gmacs", Json::Num(gmacs / tf / 1e9)),
            ("unfused_gmacs", Json::Num(gmacs / tu / 1e9)),
            ("fused_speedup_vs_unfused", Json::Num(tu / tf)),
        ]);
        fused_rows.push(row.clone());
        sink.push(row);
        println!("gemm {label}: fused epilogue {:.2}x vs gemm+requant", tu / tf);
    }

    // §Tentpole (PR 5): the register-blocked depthwise engine vs the
    // scalar MCU-faithful kernels, on the MbedNet/MCUNet block shape that
    // dominates the paper's depthwise-separable backbones. Forward (u8 +
    // f32), then both backward kernels at the §III-B sparsity levels —
    // for depthwise a masked out-channel is a masked in-channel, so the
    // kept ratio should map ~linearly onto both backward times.
    let gd = ConvGeom {
        cin: 64,
        cout: 64,
        kh: 3,
        kw: 3,
        stride: 1,
        pad_h: 1,
        pad_w: 1,
        depthwise: true,
    };
    let xd = rand_q(&mut rng, &[64, 32, 32]);
    let wd = rand_q(&mut rng, &[64, 1, 3, 3]);
    let biasd = vec![0i32; 64];
    let macsd = gd.fwd_macs(32, 32) as f64;
    let mut dw_rows: Vec<Json> = Vec::new();
    let (td_s, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(qconv::qconv2d_fwd(&xd, &wd, &biasd, &gd, oqp, true, &mut ops));
    });
    let (td_b, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(dwconv::qdwconv2d_fwd(&xd, &wd, &biasd, &gd, oqp, true, &mut ops));
    });
    tab.row(&[
        "qdwconv fwd scalar".into(),
        "64x32x32 dw, k3".into(),
        fmt_duration(td_s),
        format!("{:.2}", macsd / td_s / 1e9),
    ]);
    tab.row(&[
        "qdwconv fwd blocked".into(),
        "64x32x32 dw, k3".into(),
        fmt_duration(td_b),
        format!("{:.2}", macsd / td_b / 1e9),
    ]);
    let row = Json::obj(vec![
        ("kernel", Json::str("qdwconv2d_fwd")),
        ("shape", Json::str("64x32x32 dw k3")),
        ("scalar_seconds", Json::Num(td_s)),
        ("blocked_seconds", Json::Num(td_b)),
        ("blocked_gmacs", Json::Num(macsd / td_b / 1e9)),
        ("blocked_speedup_vs_scalar", Json::Num(td_s / td_b)),
    ]);
    dw_rows.push(row.clone());
    sink.push(row);
    println!("dwconv fwd: blocked {:.2}x vs scalar", td_s / td_b);

    // float depthwise forward pair (the float32/mixed configurations)
    let mut xdf = TensorF32::zeros(&[64, 32, 32]);
    rng.fill_normal(xdf.data_mut(), 1.0);
    let mut wdf = TensorF32::zeros(&[64, 1, 3, 3]);
    rng.fill_normal(wdf.data_mut(), 0.3);
    let bdf = vec![0f32; 64];
    let (tdf_s, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(fconv::fconv2d_fwd(&xdf, &wdf, &bdf, &gd, true, &mut ops));
    });
    let (tdf_b, _) = time_it(2, reps, || {
        let mut ops = OpCounter::new();
        std::hint::black_box(dwconv::fdwconv2d_fwd(&xdf, &wdf, &bdf, &gd, true, &mut ops));
    });
    tab.row(&[
        "fdwconv fwd scalar".into(),
        "64x32x32 dw, k3".into(),
        fmt_duration(tdf_s),
        format!("{:.2}", macsd / tdf_s / 1e9),
    ]);
    tab.row(&[
        "fdwconv fwd blocked".into(),
        "64x32x32 dw, k3".into(),
        fmt_duration(tdf_b),
        format!("{:.2}", macsd / tdf_b / 1e9),
    ]);
    let row = Json::obj(vec![
        ("kernel", Json::str("fdwconv2d_fwd")),
        ("shape", Json::str("64x32x32 dw k3")),
        ("scalar_seconds", Json::Num(tdf_s)),
        ("blocked_seconds", Json::Num(tdf_b)),
        ("blocked_speedup_vs_scalar", Json::Num(tdf_s / tdf_b)),
    ]);
    dw_rows.push(row.clone());
    sink.push(row);

    // depthwise backward at kept = 100/50/25%: scalar vs blocked (the
    // blocked path consumes the flipped pack exactly as the plan does)
    let edq = rand_q(&mut rng, &[64, 32, 32]);
    let mut dw_pack = vec![0u8; 64 * 9];
    dwconv::pack_dw_flip_u8(wd.values.data(), &gd, &mut dw_pack);
    for &kept_frac in &[1.0f64, 0.5, 0.25] {
        let kept_n = ((gd.cout as f64 * kept_frac).round() as usize).clamp(1, gd.cout);
        let mask: Vec<bool> = {
            let mut m = vec![false; gd.cout];
            for j in 0..kept_n {
                m[j * gd.cout / kept_n] = true;
            }
            m
        };
        let keep = if kept_frac >= 1.0 { None } else { Some(&mask[..]) };
        let kmacs = macsd * kept_frac;
        let label = format!("kept={:.0}%", kept_frac * 100.0);

        let (tdi_s, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(qconv::qconv2d_bwd_input(
                &edq,
                &wd,
                &gd,
                32,
                32,
                oqp,
                keep,
                &mut ops,
            ));
        });
        let (tdi_b, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(dwconv::qdwconv2d_bwd_input_packed(
                &edq,
                &wd,
                &dw_pack,
                &gd,
                32,
                32,
                oqp,
                keep,
                &mut ops,
            ));
        });
        let (tdw_s, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(qconv::qconv2d_bwd_weight(&edq, &xd, &gd, keep, &mut ops));
        });
        let (tdw_b, _) = time_it(1, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(dwconv::qdwconv2d_bwd_weight(&edq, &xd, &gd, keep, &mut ops));
        });
        tab.row(&[
            format!("qdwconv bwd_input scalar {label}"),
            "64x32x32 dw".into(),
            fmt_duration(tdi_s),
            format!("{:.2}", kmacs / tdi_s / 1e9),
        ]);
        tab.row(&[
            format!("qdwconv bwd_input blocked {label}"),
            "64x32x32 dw".into(),
            fmt_duration(tdi_b),
            format!("{:.2}", kmacs / tdi_b / 1e9),
        ]);
        tab.row(&[
            format!("qdwconv bwd_weight scalar {label}"),
            "64x32x32 dw".into(),
            fmt_duration(tdw_s),
            format!("{:.2}", kmacs / tdw_s / 1e9),
        ]);
        tab.row(&[
            format!("qdwconv bwd_weight blocked {label}"),
            "64x32x32 dw".into(),
            fmt_duration(tdw_b),
            format!("{:.2}", kmacs / tdw_b / 1e9),
        ]);
        let row = Json::obj(vec![
            ("kernel", Json::str("qdwconv2d_bwd_sparsity")),
            ("shape", Json::str("64x32x32 dw k3")),
            ("kept_fraction", Json::Num(kept_frac)),
            ("bwd_input_scalar_seconds", Json::Num(tdi_s)),
            ("bwd_input_blocked_seconds", Json::Num(tdi_b)),
            ("bwd_input_blocked_speedup", Json::Num(tdi_s / tdi_b)),
            ("bwd_weight_scalar_seconds", Json::Num(tdw_s)),
            ("bwd_weight_blocked_seconds", Json::Num(tdw_b)),
            ("bwd_weight_blocked_speedup", Json::Num(tdw_s / tdw_b)),
        ]);
        dw_rows.push(row.clone());
        sink.push(row);
        println!(
            "dwconv bwd {label}: input blocked {:.2}x, weight blocked {:.2}x vs scalar",
            tdi_s / tdi_b,
            tdw_s / tdw_b
        );
    }

    // §Tentpole (PR 8): the runtime-dispatched SIMD micro-kernels vs the
    // scalar oracle, forced through the explicit `_sel` twins so neither
    // arm depends on the process-wide TT_KERNEL mode or the autotuned
    // plan. Both arms are bit-exact on these u8/i32 paths, so the delta
    // is pure vector throughput; `bench_gate` holds the geometric mean of
    // `simd_speedup_vs_scalar` over these rows above a
    // machine-independent floor (TT_BENCH_GATE_SIMD_FLOOR). The rows are
    // emitted only when the host exposes a vector ISA — a plain scalar
    // machine produces none and the gate self-skips.
    let mut simd_rows: Vec<Json> = Vec::new();
    if let Some(isa) = simd::isa() {
        for &(label, mm, kdim, nsp) in &[
            ("stem3x3 16x27x1024", 16usize, 27usize, 1024usize),
            ("blk3x3 32x144x256", 32, 144, 256),
            ("pw 96x16x256", 96, 16, 256),
            ("pw 24x96x256", 24, 96, 256),
            ("head1x1 128x64x64", 128, 64, 64),
        ] {
            let a: Vec<u8> = (0..mm * kdim).map(|_| rng.below(256) as u8).collect();
            let bm: Vec<u8> = (0..kdim * nsp).map(|_| rng.below(256) as u8).collect();
            let init = vec![0i32; mm];
            let mut out = vec![0i32; mm * nsp];
            let gmacs = (mm * kdim * nsp) as f64;
            let (ts, _) = time_it(2, reps, || {
                gemm::gemm_u8_i32_sel(
                    KernelSel::Scalar,
                    &a,
                    3,
                    &bm,
                    5,
                    &init,
                    mm,
                    kdim,
                    nsp,
                    &mut out,
                );
                std::hint::black_box(&out);
            });
            let (tv, _) = time_it(2, reps, || {
                gemm::gemm_u8_i32_sel(
                    KernelSel::Simd(isa),
                    &a,
                    3,
                    &bm,
                    5,
                    &init,
                    mm,
                    kdim,
                    nsp,
                    &mut out,
                );
                std::hint::black_box(&out);
            });
            let Some(speedup) = safe_speedup(ts, tv) else {
                println!("gemm {label}: degenerate simd timing, row dropped");
                continue;
            };
            tab.row(&[
                format!("gemm simd ({isa:?})"),
                label.into(),
                fmt_duration(tv),
                format!("{:.2}", gmacs / tv / 1e9),
            ]);
            let row = Json::obj(vec![
                ("kernel", Json::str("gemm_simd_vs_scalar")),
                ("shape", Json::str(label)),
                ("scalar_seconds", Json::Num(ts)),
                ("simd_seconds", Json::Num(tv)),
                ("simd_gmacs", Json::Num(gmacs / tv / 1e9)),
                ("simd_speedup_vs_scalar", Json::Num(speedup)),
            ]);
            simd_rows.push(row.clone());
            sink.push(row);
            println!("gemm {label}: simd {speedup:.2}x vs scalar");
        }

        // depthwise: forward AXPY rows and the packed backward-input pass
        // on the same 64x32x32 block shape as the scalar-vs-blocked table
        let (tds, _) = time_it(2, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(dwconv::qdwconv2d_fwd_sel(
                KernelSel::Scalar,
                &xd,
                &wd,
                &biasd,
                &gd,
                oqp,
                true,
                &mut ops,
            ));
        });
        let (tdv, _) = time_it(2, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(dwconv::qdwconv2d_fwd_sel(
                KernelSel::Simd(isa),
                &xd,
                &wd,
                &biasd,
                &gd,
                oqp,
                true,
                &mut ops,
            ));
        });
        let (tis, _) = time_it(2, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(dwconv::qdwconv2d_bwd_input_sel(
                KernelSel::Scalar,
                &edq,
                &wd,
                &gd,
                32,
                32,
                oqp,
                None,
                &mut scratch,
                &mut ops,
            ));
        });
        let (tiv, _) = time_it(2, reps, || {
            let mut ops = OpCounter::new();
            std::hint::black_box(dwconv::qdwconv2d_bwd_input_sel(
                KernelSel::Simd(isa),
                &edq,
                &wd,
                &gd,
                32,
                32,
                oqp,
                None,
                &mut scratch,
                &mut ops,
            ));
        });
        for (arm, ts_a, tv_a) in [("fwd", tds, tdv), ("bwd_input", tis, tiv)] {
            let Some(speedup) = safe_speedup(ts_a, tv_a) else {
                println!("dwconv {arm}: degenerate simd timing, row dropped");
                continue;
            };
            tab.row(&[
                format!("qdwconv {arm} simd ({isa:?})"),
                "64x32x32 dw, k3".into(),
                fmt_duration(tv_a),
                format!("{:.2}", macsd / tv_a / 1e9),
            ]);
            let row = Json::obj(vec![
                ("kernel", Json::str("dwconv_simd_vs_scalar")),
                ("shape", Json::str(&format!("64x32x32 dw k3 {arm}"))),
                ("scalar_seconds", Json::Num(ts_a)),
                ("simd_seconds", Json::Num(tv_a)),
                ("simd_speedup_vs_scalar", Json::Num(speedup)),
            ]);
            simd_rows.push(row.clone());
            sink.push(row);
            println!("dwconv {arm}: simd {speedup:.2}x vs scalar");
        }
    } else {
        println!("no vector ISA on this host — simd-vs-scalar rows skipped");
    }

    // Pack-cache telemetry: a short uint8 training run (forward +
    // backward + FQT updates). After deployment warming, every dense
    // backward hits the plan-owned pack; each optimizer step invalidates
    // exactly the touched layers, which the next pass re-packs once.
    let def = models::mnist_cnn(&[1, 12, 12], 4);
    let mut prng = Pcg32::seeded(7);
    let fp = FloatParams::init(&def, &mut prng);
    let mut xs_t: Vec<TensorF32> = Vec::new();
    for _ in 0..4 {
        let mut x = TensorF32::zeros(&[1, 12, 12]);
        prng.fill_normal(x.data_mut(), 0.5);
        xs_t.push(x);
    }
    let calib = calibrate(&def, &fp, &xs_t);
    let mut model = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);
    let mut opt = FqtSgd::new(&model, 0.01, 2);
    let mut mscratch = model.make_scratch();
    let mut mops = OpCounter::new();
    let (tstep, _) = time_it(1, reps.max(4), || {
        for (i, x) in xs_t.iter().enumerate() {
            let trace = model.forward_adapt_in(x, &mut mscratch, &mut mops);
            let (_, _, err) = softmax::softmax_ce(&trace.logits, i % 4, &mut mops);
            let bwd = model.backward_in(&trace, err, &mut DenseUpdates, &mut mscratch, &mut mops);
            opt.accumulate(&mut model, &bwd, &mut mops);
        }
    });
    let ps = model.pack_stats();
    tab.row(&[
        "pack_cache".into(),
        format!("hits {} misses {} builds {}", ps.hits, ps.misses, ps.builds),
        fmt_duration(tstep),
        String::new(),
    ]);
    sink.push(Json::obj(vec![
        ("kernel", Json::str("pack_cache")),
        ("hits", Json::Num(ps.hits as f64)),
        ("misses", Json::Num(ps.misses as f64)),
        ("builds", Json::Num(ps.builds as f64)),
        ("train_pass_seconds", Json::Num(tstep)),
    ]));

    // §Tentpole (PR 7): fleet-scale multi-tenant training — N independent
    // tenant sessions adapting over one shared deployment
    // (`coordinator::fleet`). MbedNet with its trainable tail, so the
    // shared artifacts (full weights + activation plan) dominate what an
    // independent per-device deployment would replicate. Each tenant's
    // stream shifts domain mid-way; the rows carry fleet throughput
    // (tenants/s, steps/s), the per-tenant session overhead (CoW deltas +
    // replay — asserted against N× full-model cost by the
    // `memory_ratio_vs_independent` floor in `bench_gate`,
    // TT_BENCH_GATE_FLEET_FLOOR) and the aggregate online accuracy under
    // per-tenant drift.
    let fspec = spec_by_name("cifar10").expect("dataset registry");
    let mut frng = Pcg32::seeded(21);
    let fdef = models::mbednet(&[3, 12, 12], fspec.classes);
    let ffp = FloatParams::init(&fdef, &mut frng);
    let fcal = Domain::new(&fspec, [3, 12, 12], 21).splits(1, 0, &mut frng).0;
    let fcalib = calibrate(&fdef, &ffp, &fcal.xs);
    let fshared = Arc::new(ModelArtifacts::deploy(fdef, DnnConfig::Uint8, &ffp, &fcalib));
    let fleet_max = env_usize("TT_FLEET_TENANTS", 10_000).max(1);
    let mut fleet_rows: Vec<Json> = Vec::new();
    for &(n, arrivals) in &[(1usize, 40usize), (100, 6), (fleet_max, 2)] {
        let cfg = FleetConfig::builder()
            .tenants(n)
            .arrivals_per_tenant(arrivals)
            .mean_gap_s(0.05)
            .shift_at(arrivals.div_ceil(2))
            .session(
                CoordinatorConfig::builder()
                    .replay_capacity(4)
                    .max_steps_per_gap(1)
                    .warmup_samples(1)
                    .build(),
            )
            .seed(23)
            .build();
        let run_cfg = RunConfig::builder().workers(workers).build();
        let dom = Domain::new(&fspec, [3, 12, 12], 21);
        let mut fleet =
            FleetCoordinator::new(Arc::clone(&fshared), device::imxrt1062(), dom, run_cfg, cfg);
        let t0 = std::time::Instant::now();
        let rep = fleet.run();
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let steps_per_sec = rep.aggregate.train_steps as f64 / wall;
        let tenants_per_sec = n as f64 / wall;
        tab.row(&[
            format!("fleet {n} tenants x{workers} thr"),
            format!("mbednet 3x12x12, {arrivals} arrivals"),
            fmt_duration(wall),
            String::new(),
        ]);
        let row = Json::obj(vec![
            ("kernel", Json::str("fleet_session")),
            ("shape", Json::str(&format!("tenants={n}"))),
            ("tenants", Json::Num(n as f64)),
            ("arrivals_per_tenant", Json::Num(arrivals as f64)),
            ("workers", Json::Num(workers as f64)),
            ("wall_seconds", Json::Num(wall)),
            ("steps_per_sec", Json::Num(steps_per_sec)),
            ("tenants_per_sec", Json::Num(tenants_per_sec)),
            ("train_steps", Json::Num(rep.aggregate.train_steps as f64)),
            ("online_accuracy", Json::Num(rep.aggregate.online_accuracy() as f64)),
            ("shared_bytes", Json::Num(rep.shared_bytes as f64)),
            ("per_tenant_bytes", Json::Num(rep.per_tenant_bytes() as f64)),
            (
                "optimizer_bytes_per_tenant",
                Json::Num(rep.optimizer_bytes as f64 / n as f64),
            ),
            ("memory_ratio_vs_independent", Json::Num(rep.memory_ratio())),
        ]);
        fleet_rows.push(row.clone());
        sink.push(row);
        println!(
            "fleet {n} tenants: {:.0} steps/s, {:.1} tenants/s, {}B/tenant (shared {}B), \
             {:.2}x vs independent, online acc {:.3}",
            steps_per_sec,
            tenants_per_sec,
            rep.per_tenant_bytes(),
            rep.shared_bytes,
            rep.memory_ratio(),
            rep.aggregate.online_accuracy()
        );
    }

    // §Tentpole (PR 9): sub-byte packed weights. Two measurements:
    //
    //  * `subbyte_unpack_overhead` — the packed-A GEMM (`_pa_sel` twin:
    //    in-kernel unpack into lane scratch, then the identical u8 body)
    //    against the plain u8 GEMM on pre-unpacked lanes, over the same
    //    MCUNet-style shapes as the SIMD rows, so the delta is the pure
    //    per-panel unpack cost. `packed_relative_speed` (u8 time over
    //    packed time; 1.0 means the unpack is free) feeds the geomean
    //    floor in `bench_gate` (TT_BENCH_GATE_SUBBYTE_FLOOR); the gate
    //    self-skips when these rows are absent.
    //  * `subbyte_model_bytes` — per-model quantized-weight bytes the
    //    bit-selection pass reports at 8/4/2-bit storage. This is pure
    //    packing arithmetic (machine-independent), so `bench_gate` pins
    //    the 4-bit/2-bit ratios near 1/2 and 1/4.
    let mut subbyte_rows: Vec<Json> = Vec::new();
    let subbyte_sel = simd::isa().map(KernelSel::Simd).unwrap_or(KernelSel::Scalar);
    for &(label, mm, kdim, nsp) in &[
        ("stem3x3 16x27x1024", 16usize, 27usize, 1024usize),
        ("blk3x3 32x144x256", 32, 144, 256),
        ("pw 96x16x256", 96, 16, 256),
        ("pw 24x96x256", 24, 96, 256),
        ("head1x1 128x64x64", 128, 64, 64),
    ] {
        let bm: Vec<u8> = (0..kdim * nsp).map(|_| rng.below(256) as u8).collect();
        let init = vec![0i32; mm];
        let mut out = vec![0i32; mm * nsp];
        let gmacs = (mm * kdim * nsp) as f64;
        for bits in [WBits::W4, WBits::W2] {
            // Lanes already live on the narrow grid: both arms multiply
            // identical values, packed vs pre-unpacked storage.
            let lanes: Vec<u8> =
                (0..mm * kdim).map(|_| rng.below(1 << bits.bits()) as u8).collect();
            let packed = pack_lanes(&lanes, bits);
            let mut lane_buf = vec![0u8; mm * kdim];
            let (tu, _) = time_it(2, reps, || {
                gemm::gemm_u8_i32_sel(
                    subbyte_sel,
                    &lanes,
                    3,
                    &bm,
                    5,
                    &init,
                    mm,
                    kdim,
                    nsp,
                    &mut out,
                );
                std::hint::black_box(&out);
            });
            let (tp, _) = time_it(2, reps, || {
                gemm::gemm_u8_i32_pa_sel(
                    subbyte_sel,
                    &packed,
                    bits,
                    &mut lane_buf,
                    3,
                    &bm,
                    5,
                    &init,
                    mm,
                    kdim,
                    nsp,
                    &mut out,
                );
                std::hint::black_box(&out);
            });
            let Some(rel) = safe_speedup(tu, tp) else {
                println!("subbyte gemm {label} w{}: degenerate timing, row dropped", bits.bits());
                continue;
            };
            tab.row(&[
                format!("gemm packed w{}", bits.bits()),
                label.into(),
                fmt_duration(tp),
                format!("{:.2}", gmacs / tp / 1e9),
            ]);
            let row = Json::obj(vec![
                ("kernel", Json::str("subbyte_unpack_overhead")),
                ("shape", Json::str(label)),
                ("bits", Json::Num(bits.bits() as f64)),
                ("u8_seconds", Json::Num(tu)),
                ("packed_seconds", Json::Num(tp)),
                ("packed_relative_speed", Json::Num(rel)),
            ]);
            subbyte_rows.push(row.clone());
            sink.push(row);
            println!("subbyte gemm {label} w{}: {rel:.2}x relative to u8", bits.bits());
        }
    }
    let mut subbyte_model_rows: Vec<Json> = Vec::new();
    for (mname, mdef) in [
        ("mnist_cnn", models::mnist_cnn(&[1, 28, 28], 10)),
        ("mbednet", models::mbednet(&[3, 32, 32], 10)),
        ("mcunet5fps", models::mcunet5fps(&[3, 32, 32], 10)),
    ] {
        let prec = mdef.precisions(DnnConfig::Uint8);
        let bytes_at = |spec: &BitSpec| {
            ExecPlan::compile_with_bits(&mdef, DnnConfig::Uint8, true, spec)
                .bit_plan()
                .weight_bytes(&mdef, &prec)
        };
        let b8 = bytes_at(&BitSpec::default());
        let b4 = bytes_at(&BitSpec { force: Some(WBits::W4), budget: None });
        let b2 = bytes_at(&BitSpec { force: Some(WBits::W2), budget: None });
        tab.row(&[
            "subbyte weight bytes".into(),
            format!("{mname} w8/w4/w2 {b8}/{b4}/{b2}B"),
            String::new(),
            String::new(),
        ]);
        let row = Json::obj(vec![
            ("kernel", Json::str("subbyte_model_bytes")),
            ("model", Json::str(mname)),
            ("w8_bytes", Json::Num(b8 as f64)),
            ("w4_bytes", Json::Num(b4 as f64)),
            ("w2_bytes", Json::Num(b2 as f64)),
            ("w4_ratio", Json::Num(b4 as f64 / b8 as f64)),
            ("w2_ratio", Json::Num(b2 as f64 / b8 as f64)),
        ]);
        subbyte_model_rows.push(row.clone());
        sink.push(row);
        println!(
            "subbyte bytes {mname}: w8 {b8}B, w4 {b4}B ({:.3}x), w2 {b2}B ({:.3}x)",
            b4 as f64 / b8 as f64,
            b2 as f64 / b8 as f64
        );
    }

    tab.print();

    // PJRT artifact step latency, if built with the pjrt feature and the
    // artifacts exist
    #[cfg(feature = "pjrt")]
    {
        let dir = tinytrain::runtime::artifacts_dir();
        if dir.join("mnist_cnn_uint8_train.hlo.txt").exists() {
            let mut trainer =
                tinytrain::runtime::xla_trainer::load_fqt_trainer(&dir, (-2.0, 4.0), 0.01, 8, 1)
                    .expect("load artifact");
            let mut x = TensorF32::zeros(&[1, 28, 28]);
            rng.fill_normal(x.data_mut(), 0.5);
            let (ta, _) = time_it(3, reps, || {
                std::hint::black_box(trainer.train_step(&x, 3).unwrap());
            });
            println!("\nPJRT fused train step (fwd+bwd, mnist_cnn uint8): {}", fmt_duration(ta));
            sink.push(Json::obj(vec![
                ("kernel", Json::str("pjrt_train_step")),
                ("seconds", Json::Num(ta)),
            ]));
        }
    }
    // GPU forward latency vs the native engine, if built with the gpu
    // feature and an adapter (hardware or Mesa lavapipe) initializes;
    // clean-skips with a printed notice otherwise. The ratio field is
    // deliberately NOT named `*speedup*`: a software rasterizer is
    // expected to trail the native engine, and bench_gate's internal
    // ratio floor must not read that as a regression.
    #[cfg(feature = "gpu")]
    {
        use tinytrain::backend::gpu::{GpuContext, GpuPlan};

        match GpuContext::try_new() {
            None => println!("\ngpu bench: SKIP — no usable GPU adapter (hardware or lavapipe)"),
            Some(ctx) => {
                println!("\ngpu bench adapter: {}", ctx.adapter_info);
                let gpu_batch = 4usize;
                for def in tinytrain::harness::parity_models() {
                    let name = def.name.clone();
                    let fp = FloatParams::init(&def, &mut rng);
                    let mut xs = Vec::with_capacity(gpu_batch);
                    for _ in 0..gpu_batch {
                        let mut x = TensorF32::zeros(&def.input_shape);
                        rng.fill_normal(x.data_mut(), 0.5);
                        xs.push(x);
                    }
                    let calib = calibrate(&def, &fp, &xs[..2]);
                    let model =
                        NativeModel::build_with_fusion(def, DnnConfig::Uint8, &fp, &calib, false);
                    let plan = GpuPlan::new(&ctx, &model, gpu_batch);
                    let mut ops = OpCounter::new();
                    let (tn, _) = time_it(1, reps, || {
                        for x in &xs {
                            std::hint::black_box(model.forward(x, &mut ops));
                        }
                    });
                    let (tg, _) = time_it(1, reps, || {
                        std::hint::black_box(plan.forward_batch(&ctx, &xs));
                    });
                    let Some(rel) = safe_speedup(tn, tg) else {
                        println!("gpu forward {name}: degenerate timing, row dropped");
                        continue;
                    };
                    println!(
                        "gpu forward {name} (batch {gpu_batch}): native {} vs gpu {} \
                         ({rel:.2}x relative)",
                        fmt_duration(tn),
                        fmt_duration(tg)
                    );
                    sink.push(Json::obj(vec![
                        ("kernel", Json::str("gpu_forward_vs_native")),
                        ("model", Json::str(&name)),
                        ("batch", Json::Num(gpu_batch as f64)),
                        ("native_seconds", Json::Num(tn)),
                        ("gpu_seconds", Json::Num(tg)),
                        ("gpu_relative_speed", Json::Num(rel)),
                    ]));
                }
            }
        }
    }
    // Machine-readable bench baseline at the repo root: the perf
    // trajectory across PRs. `kernels` carries every JSON row of this run
    // (GMAC/s per kernel variant, plan_build, pack-cache stats, the PJRT
    // row when that feature ran); the focused micro-vs-tiled and
    // depthwise scalar-vs-blocked tables are duplicated at the top level
    // so the headline comparisons are one jq away. CI diffs this file
    // against the checked-in baseline (`bench_gate`) and uploads it as an
    // artifact next to rust/results/perf_kernels.json.
    // Schema gate: the CI perf-regression gate (`bench_gate`) diffs these
    // rows against the checked-in baseline, so they must be well-formed
    // (named, numeric, finite) before they are allowed to leave the bench.
    check_perf_rows(sink.rows()).expect("perf_kernels rows must be schema-stable");
    let baseline = Json::obj(vec![
        ("bench", Json::str("perf_kernels")),
        ("reps", Json::Num(reps as f64)),
        ("batch", Json::Num(batch as f64)),
        ("workers", Json::Num(workers as f64)),
        ("gemm_micro_vs_tiled", Json::Arr(micro_rows)),
        ("gemm_fused_epilogue", Json::Arr(fused_rows)),
        ("dwconv_scalar_vs_blocked", Json::Arr(dw_rows)),
        ("simd_vs_scalar", Json::Arr(simd_rows)),
        ("fleet_sessions", Json::Arr(fleet_rows)),
        ("subbyte_unpack_overhead", Json::Arr(subbyte_rows)),
        ("subbyte_model_bytes", Json::Arr(subbyte_model_rows)),
        (
            "pack_cache",
            Json::obj(vec![
                ("hits", Json::Num(ps.hits as f64)),
                ("misses", Json::Num(ps.misses as f64)),
                ("builds", Json::Num(ps.builds as f64)),
            ]),
        ),
        ("kernels", Json::Arr(sink.rows().to_vec())),
    ]);
    let bench_path = std::path::Path::new("../BENCH_kernels.json");
    std::fs::write(bench_path, baseline.to_string()).expect("write BENCH_kernels.json");
    println!("bench baseline -> {}", bench_path.display());

    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
