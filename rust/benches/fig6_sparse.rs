//! Fig. 6 — dynamic sparse gradient updates: accuracy for λ_min ∈
//! {1.0, 0.5, 0.1} across all seven TL datasets and three configurations
//! (6a–c), plus the per-sample backward speedup on the IMXRT1062 for the
//! mixed configuration (6d; paper: avg ≈6.6× at λ_min = 0.1, up to 8.7×).

use tinytrain::data::{transfer_specs, Domain};
use tinytrain::device;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::util::bench::{ResultSink, Table};
use tinytrain::util::json::Json;

fn main() {
    let knobs = Knobs::from_env();
    println!("Fig. 6 reproduction — knobs: {knobs:?} (paper: 20 epochs, 5 runs)");
    let lambdas = [1.0f32, 0.5, 0.1];
    let dev = device::imxrt1062();
    let mut sink = ResultSink::new("fig6_sparse");

    for cfg in [DnnConfig::Mixed, DnnConfig::Uint8, DnnConfig::Float32] {
        let mut tab = Table::new(
            &format!("Fig. 6 — accuracy under sparse updates ({})", cfg.name()),
            &["dataset", "λ=1.0", "λ=0.5", "λ=0.1"],
        );
        let mut speed_tab = Table::new(
            "Fig. 6d — backward speedup vs dense (mixed, IMXRT1062)",
            &["dataset", "λ=1.0", "λ=0.5", "λ=0.1"],
        );
        let mut speedup_acc = vec![Vec::new(); lambdas.len()];
        for spec in transfer_specs() {
            let src = Domain::new(&spec, spec.reduced_shape, 60);
            let def = harness::mbednet_for(&spec, &spec.reduced_shape);
            let (fp, _) = harness::pretrain(&def, &src, knobs.epochs, &knobs, 61);
            let mut row = vec![spec.name.to_string()];
            let mut srow = vec![spec.name.to_string()];
            let mut dense_bwd = 0.0f64;
            for (li, &lambda) in lambdas.iter().enumerate() {
                let mut accs = Vec::new();
                let mut bwd_s = 0.0;
                for run in 0..knobs.runs {
                    let mut scen =
                        harness::tl_scenario(&spec, cfg, &fp, &src, &knobs, 70 + run as u64);
                    let rep = harness::run_tl(&mut scen, lambda, &knobs, 80 + run as u64);
                    accs.push(rep.final_test_acc());
                    if run == 0 {
                        let (_, b) =
                            harness::step_costs(&mut scen.model, &scen.train, &dev, lambda);
                        bwd_s = b.seconds;
                    }
                }
                let (m, s) = harness::mean_std(&accs);
                row.push(format!("{m:.3}±{s:.3}"));
                if li == 0 {
                    dense_bwd = bwd_s;
                }
                let speedup = dense_bwd / bwd_s;
                srow.push(format!("{speedup:.2}x"));
                speedup_acc[li].push(speedup as f32);
                sink.push(Json::obj(vec![
                    ("fig", Json::str("6abc")),
                    ("dataset", Json::str(spec.name)),
                    ("config", Json::str(cfg.name())),
                    ("lambda_min", Json::Num(lambda as f64)),
                    ("acc_mean", Json::Num(m as f64)),
                    ("acc_std", Json::Num(s as f64)),
                    ("bwd_speedup", Json::Num(speedup)),
                ]));
            }
            tab.row(&row);
            if cfg == DnnConfig::Mixed {
                speed_tab.row(&srow);
            }
        }
        tab.print();
        if cfg == DnnConfig::Mixed {
            speed_tab.print();
            for (li, &lambda) in lambdas.iter().enumerate() {
                let (m, _) = harness::mean_std(&speedup_acc[li]);
                println!("average bwd speedup at λ_min={lambda}: {m:.2}x (paper λ=0.1: ≈6.64x)");
            }
        }
    }
    println!("\nexpected shape: λ=0.5 lossless everywhere; λ=0.1 lossless for float/mixed");
    println!("but degraded/unstable for uint8 (paper §IV-C).");
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
