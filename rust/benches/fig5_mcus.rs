//! Fig. 5 — transfer learning across MCUs: per-sample latency (a) and
//! energy (b) for cwru and daliac on all three Tab. II devices, all three
//! configurations; rows are marked when the deployment does not fit the
//! device (the paper could only deploy a subset).

use tinytrain::data::{spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::util::bench::{fmt_duration, ResultSink, Table};
use tinytrain::util::json::Json;

fn main() {
    let knobs = Knobs::from_env();
    println!("Fig. 5 reproduction — knobs: {knobs:?}");
    let mut tab = Table::new(
        "Fig. 5 — latency and energy per training sample across MCUs",
        &["dataset", "device", "config", "latency", "energy", "fits"],
    );
    let mut sink = ResultSink::new("fig5_mcus");

    for name in ["cwru", "daliac"] {
        let spec = spec_by_name(name).unwrap();
        let src = Domain::new(&spec, spec.reduced_shape, 50);
        let def = harness::mbednet_for(&spec, &spec.reduced_shape);
        let (fp, _) = harness::pretrain(&def, &src, 1.max(knobs.epochs / 2), &knobs, 51);
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let mut scen = harness::tl_scenario(&spec, cfg, &fp, &src, &knobs, 52);
            let mem = harness::tl_memory(&spec, cfg);
            for dev in device::all_devices() {
                let (f, b) = harness::step_costs(&mut scen.model, &scen.train, &dev, 1.0);
                let total = f.seconds + b.seconds;
                let energy = f.joules + b.joules;
                let fits = dev.fits(mem.total_ram(), mem.flash);
                tab.row(&[
                    name.into(),
                    dev.name.into(),
                    cfg.name().into(),
                    fmt_duration(total),
                    format!("{:.3} mJ", energy * 1e3),
                    if fits { "yes".into() } else { "NO (paper: not deployable)".into() },
                ]);
                sink.push(Json::obj(vec![
                    ("dataset", Json::str(name)),
                    ("device", Json::str(dev.name)),
                    ("config", Json::str(cfg.name())),
                    ("latency_s", Json::Num(total)),
                    ("energy_j", Json::Num(energy)),
                    ("planned_peak_bytes", Json::Num(mem.planned_peak_bytes as f64)),
                    ("fits", Json::Bool(fits)),
                ]));
            }
        }
    }
    tab.print();
    println!("\nexpected shape: IMXRT fastest; nrf52840 beats RP2040 despite the lower");
    println!("clock (SIMD+FPU, Fig. 5a); energy/sample: IMXRT best, nrf52840 worst (Fig. 5b).");
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
