//! Ablations of the FQT optimizer's design choices (DESIGN.md §8 calls
//! these out; the paper motivates them in §III-A):
//!
//!  * **gradient standardization** (Eq. 8) — off reproduces raw quantized
//!    SGD on deep stacks (vanishing/unstable updates);
//!  * **dynamic weight-range adaptation** (Eqs. 6–7) — off freezes the
//!    deployed scale/zero-point, the naive-int8 failure mode of Tab. IV;
//!  * **activation-range adaptation** (our Eqs. 6–7 analogue for
//!    activations; see `NativeModel::forward_adapt`) — exercised implicitly: it is part of
//!    `forward_adapt`, and the frozen-weight ablation shows the combined
//!    stall.
//!
//! Full on-device uint8 training on the EMNIST-Digits stand-in.

use tinytrain::data::spec_by_name;
use tinytrain::graph::exec::{calibrate, FloatParams, NativeModel};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::harness::{self, Knobs};
use tinytrain::train::fqt::FqtSgd;
use tinytrain::train::loop_::{self, Sparsity};
use tinytrain::util::bench::{ResultSink, Table};
use tinytrain::util::json::Json;
use tinytrain::util::prng::Pcg32;

fn run(standardize: bool, adapt_range: bool, knobs: &Knobs, seed: u64) -> (f32, f32) {
    let spec = spec_by_name("emnist-digits").unwrap();
    let mut rng = Pcg32::new(seed, 0xAA);
    let dom = tinytrain::data::Domain::new(&spec, spec.reduced_shape, seed);
    let (tr, te) = dom.splits(knobs.train_pc * 2, knobs.test_pc * 2, &mut rng);
    let def = models::mnist_cnn(&spec.reduced_shape, spec.classes);
    let fp = FloatParams::init(&def, &mut rng);
    let calib = calibrate(&def, &fp, &tr.xs[..4]);
    let mut m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);
    // lr from the environment: standardized updates have unit magnitude, so
    // the stable lr regime is narrower (the paper trains at lr 1e-3)
    let lr = std::env::var("TT_LR").ok().and_then(|v| v.parse().ok()).unwrap_or(harness::LR);
    let mut opt = FqtSgd::new(&m, lr, harness::BATCH);
    opt.standardize = standardize;
    opt.adapt_range = adapt_range;
    let rep =
        loop_::train(&mut m, &mut opt, &tr, &te, knobs.epochs, &mut Sparsity::Dense, &mut rng);
    (rep.final_test_acc(), rep.epochs.last().unwrap().train_loss)
}

fn main() {
    let knobs = Knobs::from_env();
    println!("FQT ablations — knobs: {knobs:?}");
    let mut tab = Table::new(
        "FQT optimizer ablations (uint8 full training, EMNIST-Digits stand-in)",
        &["variant", "Eq.8 std", "Eqs.6-7 range", "test acc (mean)", "final loss"],
    );
    let mut sink = ResultSink::new("ablations");
    let variants: [(&str, bool, bool); 4] = [
        ("full FQT (ours)", true, true),
        ("no standardization", false, true),
        ("frozen weight ranges", true, false),
        ("neither (naive FQT)", false, false),
    ];
    for (name, std_, ar) in variants {
        let mut accs = Vec::new();
        let mut losses = Vec::new();
        for run_i in 0..knobs.runs.max(2) {
            let (a, l) = run(std_, ar, &knobs, 900 + run_i as u64);
            accs.push(a);
            losses.push(l);
        }
        let (am, _) = harness::mean_std(&accs);
        let (lm, _) = harness::mean_std(&losses);
        tab.row(&[
            name.into(),
            std_.to_string(),
            ar.to_string(),
            format!("{am:.3}"),
            format!("{lm:.3}"),
        ]);
        sink.push(Json::obj(vec![
            ("variant", Json::str(name)),
            ("standardize", Json::Bool(std_)),
            ("adapt_range", Json::Bool(ar)),
            ("acc", Json::Num(am as f64)),
            ("loss", Json::Num(lm as f64)),
        ]));
    }
    tab.print();
    println!("\nexpected shape: full FQT best; each ablation costs accuracy, with the");
    println!("double ablation ≈ the naive int8 row of Tab. IV.");
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
