//! Fig. 3 — gradient-structure statistics behind the sparse-update
//! hypotheses: for the flowers stand-in, the per-structure gradient
//! magnitudes of the last three weighted layers after epoch 1 vs a later
//! epoch. The paper's three observations must hold:
//!   (a) magnitudes shrink through the backward pass (deeper layers
//!       carry smaller gradients),
//!   (b) high-magnitude structures get sparser for earlier layers,
//!   (c) overall magnitude decreases as training progresses.

use tinytrain::data::{spec_by_name, Domain};
use tinytrain::graph::exec::DenseUpdates;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::kernels::OpCounter;
use tinytrain::util::bench::{ResultSink, Table};
use tinytrain::util::json::Json;
use tinytrain::util::stats;

fn grad_structure_norms(
    model: &mut tinytrain::graph::exec::NativeModel,
    split: &tinytrain::train::loop_::Split,
) -> Vec<(usize, Vec<f32>)> {
    let mut ops = OpCounter::new();
    let (_, _, bwd) = model.train_sample(&split.xs[0], split.ys[0], &mut DenseUpdates, &mut ops);
    bwd.grads
        .iter()
        .enumerate()
        .filter_map(|(i, g)| {
            g.as_ref().map(|g| {
                let norms: Vec<f32> =
                    (0..g.gw.outer_dim()).map(|c| stats::l1(g.gw.outer(c))).collect();
                (i, norms)
            })
        })
        .collect()
}

fn sparsity_ratio(norms: &[f32]) -> f32 {
    // fraction of structures whose norm is below 25% of the max
    let mx = norms.iter().cloned().fold(0.0f32, f32::max).max(1e-12);
    norms.iter().filter(|&&n| n < 0.25 * mx).count() as f32 / norms.len() as f32
}

fn main() {
    let mut knobs = Knobs::from_env();
    knobs.epochs = knobs.epochs.max(6);
    println!("Fig. 3 reproduction — knobs: {knobs:?}");
    let mut spec = spec_by_name("flowers").unwrap();
    spec.reduced_shape = [3, 24, 24];
    let src = Domain::new(&spec, spec.reduced_shape, 30);
    let def = harness::mbednet_for(&spec, &spec.reduced_shape);
    let (fp, _) = harness::pretrain(&def, &src, knobs.epochs, &knobs, 31);
    let mut scen = harness::tl_scenario(&spec, DnnConfig::Mixed, &fp, &src, &knobs, 32);

    // epoch 1
    let k1 = Knobs { epochs: 1, ..knobs };
    harness::run_tl(&mut scen, 1.0, &k1, 33);
    let early = grad_structure_norms(&mut scen.model, &scen.train);
    // later epochs
    let kn = Knobs { epochs: knobs.epochs - 1, ..knobs };
    harness::run_tl(&mut scen, 1.0, &kn, 34);
    let late = grad_structure_norms(&mut scen.model, &scen.train);

    let mut tab = Table::new(
        "Fig. 3 — per-structure |grad| statistics, last trainable layers",
        &["layer", "when", "mean |g|", "max |g|", "sparsity (<25% of max)"],
    );
    let mut sink = ResultSink::new("fig3_heatmaps");
    for (tag, set) in [("epoch 1", &early), ("late", &late)] {
        for (layer, norms) in set.iter().rev().take(3) {
            tab.row(&[
                format!("L{layer}"),
                tag.into(),
                format!("{:.4}", stats::mean(norms)),
                format!("{:.4}", norms.iter().cloned().fold(0.0f32, f32::max)),
                format!("{:.2}", sparsity_ratio(norms)),
            ]);
            sink.push(Json::obj(vec![
                ("layer", Json::Num(*layer as f64)),
                ("when", Json::str(tag)),
                ("mean_g", Json::Num(stats::mean(norms) as f64)),
                ("sparsity", Json::Num(sparsity_ratio(norms) as f64)),
                ("norms", Json::arr_f32(norms)),
            ]));
        }
    }
    tab.print();

    // headline checks
    let mean_of = |set: &[(usize, Vec<f32>)]| -> f32 {
        stats::mean(&set.iter().flat_map(|(_, n)| n.iter().cloned()).collect::<Vec<_>>())
    };
    println!(
        "\noverall mean |g|: epoch1={:.5} late={:.5} (expect decrease, obs. c)",
        mean_of(&early),
        mean_of(&late)
    );
    let p = sink.flush().expect("write results");
    println!("results -> {}", p.display());
}
