"""Pure-jnp oracles for the Pallas FQT kernels — the correctness reference
pytest checks the kernels against (and, transitively, the Rust native
kernels via the PJRT cross-validation test)."""

import jax.numpy as jnp


def round_half_away(x):
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def qmatmul_ref(a_q, b_q, za, zb, mult, zo, relu=False):
    """Reference requantizing matmul (Eqs. 3/4), plain jnp."""
    acc = (a_q.astype(jnp.int32) - za) @ (b_q.astype(jnp.int32) - zb)
    v = round_half_away(acc.astype(jnp.float32) * mult).astype(jnp.int32) + zo
    lo = max(zo, 0) if relu else 0
    return jnp.clip(v, lo, 255).astype(jnp.uint8)


def qmatmul_acc_ref(a_q, b_q, za, zb):
    """Reference accumulator-only matmul (Eq. 2)."""
    return (a_q.astype(jnp.int32) - za) @ (b_q.astype(jnp.int32) - zb)


def quantize_ref(x, scale, zp):
    """uint8 affine quantization with the shared rounding rule."""
    return jnp.clip(round_half_away(x / scale).astype(jnp.int32) + zp, 0, 255).astype(jnp.uint8)


def dequantize_ref(q, scale, zp):
    return (q.astype(jnp.int32) - zp).astype(jnp.float32) * scale
