"""L1 — Pallas kernels for fully quantized training.

The paper's central observation (§III-A) is that the forward pass (Eq. 3),
the error backprop (Eq. 1/4) and the weight gradient (Eq. 2) are all the
*same* operation — a quantized matmul with transposed operands. We therefore
express the FQT hot-spot as two Pallas kernels:

  * ``qmatmul``      — u8×u8 → i32 accumulate → requantize → u8 (Eqs. 3/4),
  * ``qmatmul_acc``  — u8×u8 → i32 accumulate, no requantization (Eq. 2:
                       weight gradients stay in float space for the SGD
                       step, so the i32 accumulator is returned directly).

Convolutions are lowered onto these kernels via im2col (`conv_as_matmul`
below), which is also the TPU adaptation story (DESIGN.md
§Hardware-Adaptation): the quantized conv becomes a blocked matmul that
the MXU would execute, with BlockSpec tiles sized for VMEM.

Numerics contract (bit-exact with `rust/src/kernels/`): i32 accumulation,
requantization ``clamp(round_half_away(acc * mult) + z_out, lo, 255)`` with
``lo = z_out`` when the folded ReLU is active. ``interpret=True`` throughout
(the CPU PJRT plugin cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes for the M/N grid. K is kept whole per block (the reduction
# fits VMEM for every layer in the evaluation; see DESIGN.md §Perf for the
# footprint table).
BLOCK_M = 32
BLOCK_N = 128


def round_half_away(x):
    """Round half away from zero (matches Rust ``f32::round``).

    ``jnp.round`` rounds half to even, which would diverge from the MCU
    kernels on exact .5 boundaries.
    """
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def _qmatmul_kernel(a_ref, b_ref, za_ref, zb_ref, mult_ref, zo_ref, o_ref, *, relu):
    """One (BLOCK_M, BLOCK_N) output tile of the requantizing matmul."""
    a = a_ref[...].astype(jnp.int32) - za_ref[0]
    b = b_ref[...].astype(jnp.int32) - zb_ref[0]
    acc = jax.lax.dot_general(
        a,
        b,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    v = round_half_away(acc.astype(jnp.float32) * mult_ref[0]).astype(jnp.int32) + zo_ref[0]
    lo = jnp.where(relu, jnp.maximum(zo_ref[0], 0), 0)
    o_ref[...] = jnp.clip(v, lo, 255).astype(jnp.uint8)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qmatmul(a_q, b_q, za, zb, mult, zo, relu=False):
    """Quantized matmul with requantization: Eqs. 3/4.

    a_q: u8[M, K], b_q: u8[K, N]; za/zb/zo zero points (i32 scalars),
    mult = s_a*s_b/s_out (f32 scalar). Returns u8[M, N].

    Padding note: rows/cols are padded *with the zero points* so padded
    positions contribute exactly zero to the accumulator.
    """
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    za_a = jnp.asarray([za], jnp.int32)
    zb_a = jnp.asarray([zb], jnp.int32)
    mult_a = jnp.asarray([mult], jnp.float32)
    zo_a = jnp.asarray([zo], jnp.int32)

    ap = _pad_to(a_q + jnp.uint8(0), BLOCK_M, 0)
    bp = _pad_to(b_q + jnp.uint8(0), BLOCK_N, 1)
    # pad K positions with the zero points (zero contribution)
    if ap.shape[0] != m:
        ap = ap.at[m:, :].set(jnp.asarray(za, jnp.uint8))
    if bp.shape[1] != n:
        bp = bp.at[:, n:].set(jnp.asarray(zb, jnp.uint8))
    mp, np_ = ap.shape[0], bp.shape[1]

    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, relu=relu),
        grid=(mp // BLOCK_M, np_ // BLOCK_N),
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BLOCK_N), lambda i, j: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.uint8),
        interpret=True,
    )(ap, bp, za_a, zb_a, mult_a, zo_a)
    return out[:m, :n]


def _qmatmul_acc_kernel(a_ref, b_ref, za_ref, zb_ref, o_ref):
    a = a_ref[...].astype(jnp.int32) - za_ref[0]
    b = b_ref[...].astype(jnp.int32) - zb_ref[0]
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def qmatmul_acc(a_q, b_q, za, zb):
    """Quantized matmul returning the raw i32 accumulator (Eq. 2 —
    gradients are not requantized; the caller scales by ``s_a·s_b``)."""
    m, k = a_q.shape
    _, n = b_q.shape
    za_a = jnp.asarray([za], jnp.int32)
    zb_a = jnp.asarray([zb], jnp.int32)
    ap = _pad_to(a_q + jnp.uint8(0), BLOCK_M, 0)
    bp = _pad_to(b_q + jnp.uint8(0), BLOCK_N, 1)
    if ap.shape[0] != m:
        ap = ap.at[m:, :].set(jnp.asarray(za, jnp.uint8))
    if bp.shape[1] != n:
        bp = bp.at[:, n:].set(jnp.asarray(zb, jnp.uint8))
    mp, np_ = ap.shape[0], bp.shape[1]
    out = pl.pallas_call(
        _qmatmul_acc_kernel,
        grid=(mp // BLOCK_M, np_ // BLOCK_N),
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BLOCK_N), lambda i, j: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(ap, bp, za_a, zb_a)
    return out[:m, :n]


# --------------------------------------------------------------------------
# conv <-> matmul plumbing (build-time jnp; lowers into the same HLO)
# --------------------------------------------------------------------------


def im2col(x, kh, kw, stride, pad_h, pad_w, pad_value):
    """[C,H,W] -> [C·kh·kw, Oh·Ow] patch matrix, padding with `pad_value`
    (the input zero point, so padded taps contribute zero)."""
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad_h, pad_h), (pad_w, pad_w)), constant_values=pad_value)
    oh = (h + 2 * pad_h - kh) // stride + 1
    ow = (w + 2 * pad_w - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            sl = xp[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            cols.append(sl.reshape(c, oh * ow))
    # order: [C, kh*kw, Oh*Ow] -> [C*kh*kw, Oh*Ow] with C-major layout to
    # match the Rust weight layout [Cout, Cin, Kh, Kw]
    return jnp.stack(cols, axis=1).reshape(c * kh * kw, oh * ow), (oh, ow)


def col2im(cols, c, h, w, kh, kw, stride, pad_h, pad_w):
    """Adjoint of im2col: scatter-add [C·kh·kw, Oh·Ow] back to [C,H,W]."""
    oh = (h + 2 * pad_h - kh) // stride + 1
    ow = (w + 2 * pad_w - kw) // stride + 1
    xp = jnp.zeros((c, h + 2 * pad_h, w + 2 * pad_w), cols.dtype)
    cols = cols.reshape(c, kh * kw, oh, ow)
    i = 0
    for dy in range(kh):
        for dx in range(kw):
            patch = cols[:, i]
            xp = xp.at[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride].add(patch)
            i += 1
    return xp[:, pad_h : pad_h + h, pad_w : pad_w + w]


def qconv2d(x_q, w_q, bias_i32, zx, zw, mult, zo, stride, pad, relu):
    """Quantized conv via im2col + the Pallas qmatmul.

    x_q u8[C,H,W], w_q u8[Cout, C*kh*kw] (pre-flattened), bias i32[Cout]
    at scale s_x*s_w. Bias is folded into the accumulator by pre-biasing
    the product: we add round(bias*mult) post-requant would lose precision,
    so instead bias is added via the accumulator path: qmatmul_acc + manual
    requant would duplicate the kernel; we use the identity
    (acc + bias) requant == requant kernel with bias folded into `a`? No —
    we simply compute acc with qmatmul_acc, add bias, and requantize in jnp
    (same formula as the kernel; bit-identical because the math is the
    same sequence of f32 ops).
    """
    c, h, w = x_q.shape
    cout = w_q.shape[0]
    kh = kw = 3 if w_q.shape[1] == c * 9 else 1
    cols, (oh, ow) = im2col(x_q, kh, kw, stride, pad, pad, jnp.uint8(zx) if isinstance(zx, int) else zx.astype(jnp.uint8))
    acc = qmatmul_acc(w_q, cols, zw, zx) + bias_i32[:, None]
    v = round_half_away(acc.astype(jnp.float32) * mult).astype(jnp.int32) + zo
    lo = jnp.where(relu, jnp.maximum(zo, 0), 0)
    y = jnp.clip(v, lo, 255).astype(jnp.uint8)
    return y.reshape(cout, oh, ow)


def requantize(acc_i32, mult, zo, relu=False):
    """jnp requantization with the shared rounding rule."""
    v = round_half_away(acc_i32.astype(jnp.float32) * mult).astype(jnp.int32) + zo
    lo = jnp.where(relu, jnp.maximum(zo, 0), 0)
    return jnp.clip(v, lo, 255).astype(jnp.uint8)
