"""L2 — JAX train-step graphs, calling the L1 Pallas kernels.

Two AOT-compiled train steps for the §IV-D full-training network
(2 conv + maxpool + 2 linear; identical geometry to
`rust/src/graph/models.rs::mnist_cnn` at 1×28×28 / 10 classes):

  * ``fqt_train_step``   — the fully quantized (uint8) configuration:
    quantized forward (Pallas qmatmul via im2col), float softmax-CE head,
    quantized backward per Eqs. 1–4, float weight gradients (Eq. 2, not
    requantized). Quantization parameters are *runtime inputs* (packed in
    one f32 vector) so the Rust coordinator can adapt weight/activation/
    error ranges between steps (Eqs. 5–7) without recompiling.
  * ``float_train_step`` — the float32 reference configuration via
    ``jax.grad``.

Both are lowered once by ``aot.py`` to HLO text; the Rust runtime executes
them via PJRT. Python never runs at training time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import qops

# ---- architecture constants (must match rust/src/graph/models.rs) --------
IN_SHAPE = (1, 28, 28)
N_CLASSES = 10
C1, C2, FC1 = 16, 32, 64
# conv1: 1x28x28 -> 16x14x14; conv2 -> 32x7x7; pool -> 32x3x3; flat 288
FLAT = C2 * 3 * 3

# ---- qparams vector layout (f32[26]) --------------------------------------
# [0]  s_in   [1]  z_in
# [2]  s_w1   [3]  z_w1   [4]  s_a1   [5]  z_a1
# [6]  s_w2   [7]  z_w2   [8]  s_a2   [9]  z_a2
# [10] s_w4   [11] z_w4   [12] s_a4   [13] z_a4
# [14] s_w5   [15] z_w5   [16] s_a5   [17] z_a5   (logits)
# [18] s_e5   [19] z_e5   (error at logits)
# [20] s_e4   [21] z_e4   (error at fc1 output)
# [22] s_e2   [23] z_e2   (error at conv2 output / pool)
# [24] s_e1   [25] z_e1   (error at conv1 output)
QP_LEN = 26


def _zi(qp, i):
    return qp[i].astype(jnp.int32)


def fqt_train_step(x_q, onehot, w1, b1, w2, b2, w4, b4, w5, b5, qp):
    """One fully quantized training-sample pass.

    Inputs: x_q u8[1,28,28]; onehot f32[10]; conv weights pre-flattened
    u8[Cout, Cin·9]; linear weights u8[Out, In]; biases f32; qp f32[26].

    Returns (loss, logits, gw1, gb1, gw2, gb2, gw4, gb4, gw5, gb5,
    err_minmax f32[4,2], sat f32[4]).
    """
    s_in, z_in = qp[0], _zi(qp, 1)

    # ---------------- forward (Eq. 3) ----------------
    # conv1
    m1 = qp[0] * qp[2] / qp[4]
    bi1 = qops.round_half_away(b1 / (qp[0] * qp[2])).astype(jnp.int32)
    cols0, (oh1, ow1) = qops.im2col(x_q, 3, 3, 2, 1, 1, z_in.astype(jnp.uint8))
    acc1 = qops.qmatmul_acc(w1, cols0, _zi(qp, 3), z_in) + bi1[:, None]
    a1 = qops.requantize(acc1, m1, _zi(qp, 5), relu=True)  # [16, 196]
    a1_img = a1.reshape(C1, oh1, ow1)

    # conv2
    m2 = qp[4] * qp[6] / qp[8]
    bi2 = qops.round_half_away(b2 / (qp[4] * qp[6])).astype(jnp.int32)
    cols1, (oh2, ow2) = qops.im2col(a1_img, 3, 3, 2, 1, 1, qp[5].astype(jnp.uint8))
    acc2 = qops.qmatmul_acc(w2, cols1, _zi(qp, 7), _zi(qp, 5)) + bi2[:, None]
    a2 = qops.requantize(acc2, m2, _zi(qp, 9), relu=True).reshape(C2, oh2, ow2)

    # maxpool 2 (crop 7->6, first-occurrence argmax like the Rust kernel)
    a2c = a2[:, :6, :6].reshape(C2, 3, 2, 3, 2).transpose(0, 1, 3, 2, 4).reshape(C2, 9, 4)
    am = jnp.argmax(a2c, axis=-1)  # first max wins
    a3 = jnp.take_along_axis(a2c, am[..., None], axis=-1)[..., 0]  # [32, 9]
    a3_flat = a3.reshape(FLAT)  # qp of a2

    # fc1
    m4 = qp[8] * qp[10] / qp[12]
    bi4 = qops.round_half_away(b4 / (qp[8] * qp[10])).astype(jnp.int32)
    acc4 = qops.qmatmul_acc(w4, a3_flat[:, None], _zi(qp, 11), _zi(qp, 9))[:, 0] + bi4
    a4 = qops.requantize(acc4, m4, _zi(qp, 13), relu=True)  # [64]

    # fc2 (logits, no relu)
    m5 = qp[12] * qp[14] / qp[16]
    bi5 = qops.round_half_away(b5 / (qp[12] * qp[14])).astype(jnp.int32)
    acc5 = qops.qmatmul_acc(w5, a4[:, None], _zi(qp, 15), _zi(qp, 13))[:, 0] + bi5
    a5 = qops.requantize(acc5, m5, _zi(qp, 17), relu=False)  # [10]

    logits = (a5.astype(jnp.int32) - _zi(qp, 17)).astype(jnp.float32) * qp[16]

    # ---------------- loss + head error ----------------
    lmax = jnp.max(logits)
    lse = lmax + jnp.log(jnp.sum(jnp.exp(logits - lmax)))
    loss = lse - jnp.sum(logits * onehot)
    probs = jnp.exp(logits - lse)
    e5_f = probs - onehot
    e5 = qops.requantize(
        qops.round_half_away(e5_f / qp[18]).astype(jnp.int32), 1.0, _zi(qp, 19), relu=False
    )
    # (requantize with mult=1 just clamps round(e/s)+z, matching Rust
    # QTensor::quantize_with)

    # ---------------- backward (Eqs. 1, 2, 4) ----------------
    # fc2: gw5 = (e5 - z)(a4 - z)^T, float (Eq. 2, no requant)
    de5 = e5.astype(jnp.int32) - _zi(qp, 19)
    gw5 = (de5[:, None] * (a4.astype(jnp.int32) - _zi(qp, 13))[None, :]).astype(jnp.float32) * (
        qp[18] * qp[12]
    )
    gb5 = de5.astype(jnp.float32) * qp[18]
    # e4 = W5^T e5, requantized at (s_e4, z_e4)
    acc_e4 = qops.qmatmul_acc(w5.T, e5[:, None], _zi(qp, 15), _zi(qp, 19))[:, 0]
    e4_f_lo = jnp.min(acc_e4).astype(jnp.float32) * (qp[14] * qp[18])
    e4_f_hi = jnp.max(acc_e4).astype(jnp.float32) * (qp[14] * qp[18])
    me4 = qp[14] * qp[18] / qp[20]
    e4 = qops.requantize(acc_e4, me4, _zi(qp, 21), relu=False)
    # relu mask at fc1 output
    e4 = jnp.where(a4 > _zi(qp, 13).astype(jnp.uint8), e4, _zi(qp, 21).astype(jnp.uint8))

    # fc1: gw4, gb4; e3 = W4^T e4
    de4 = e4.astype(jnp.int32) - _zi(qp, 21)
    gw4 = (de4[:, None] * (a3_flat.astype(jnp.int32) - _zi(qp, 9))[None, :]).astype(
        jnp.float32
    ) * (qp[20] * qp[8])
    gb4 = de4.astype(jnp.float32) * qp[20]
    acc_e3 = qops.qmatmul_acc(w4.T, e4[:, None], _zi(qp, 11), _zi(qp, 21))[:, 0]
    e3_lo = jnp.min(acc_e3).astype(jnp.float32) * (qp[10] * qp[20])
    e3_hi = jnp.max(acc_e3).astype(jnp.float32) * (qp[10] * qp[20])
    me3 = qp[10] * qp[20] / qp[22]
    e3 = qops.requantize(acc_e3, me3, _zi(qp, 23), relu=False)  # [288], qp e2

    # maxpool backward: route to argmax positions, z_e2 elsewhere
    e3_win = e3.reshape(C2, 9)
    e2c = jnp.full((C2, 9, 4), _zi(qp, 23), jnp.uint8)
    e2c = jnp.put_along_axis(e2c, am[..., None], e3_win[..., None], axis=-1, inplace=False)
    e2_crop = e2c.reshape(C2, 3, 3, 2, 2).transpose(0, 1, 3, 2, 4).reshape(C2, 6, 6)
    e2 = jnp.full((C2, 7, 7), _zi(qp, 23), jnp.uint8)
    e2 = e2.at[:, :6, :6].set(e2_crop)
    # relu mask at conv2 output
    e2 = jnp.where(a2 > _zi(qp, 9).astype(jnp.uint8), e2, _zi(qp, 23).astype(jnp.uint8))
    e2_mat = e2.reshape(C2, oh2 * ow2)

    # conv2: gw2 = (e2 - z)(cols1 - z)^T * s_e2*s_a1; e1 via col2im(W2^T e2)
    de2 = e2_mat.astype(jnp.int32) - _zi(qp, 23)
    gw2 = (
        qops.qmatmul_acc(e2_mat, cols1.T, _zi(qp, 23), _zi(qp, 5)).astype(jnp.float32)
        * (qp[22] * qp[4])
    )
    gb2 = jnp.sum(de2, axis=1).astype(jnp.float32) * qp[22]
    cols_e1 = qops.qmatmul_acc(w2.T, e2_mat, _zi(qp, 7), _zi(qp, 23))  # [144, 49] i32
    acc_e1 = qops.col2im(cols_e1, C1, 14, 14, 3, 3, 2, 1, 1)  # i32 [16,14,14]
    e1_lo = jnp.min(acc_e1).astype(jnp.float32) * (qp[6] * qp[22])
    e1_hi = jnp.max(acc_e1).astype(jnp.float32) * (qp[6] * qp[22])
    me1 = qp[6] * qp[22] / qp[24]
    e1 = qops.requantize(acc_e1, me1, _zi(qp, 25), relu=False)
    e1 = jnp.where(a1_img > _zi(qp, 5).astype(jnp.uint8), e1, _zi(qp, 25).astype(jnp.uint8))
    e1_mat = e1.reshape(C1, oh1 * ow1)

    # conv1 weight grads
    de1 = e1_mat.astype(jnp.int32) - _zi(qp, 25)
    gw1 = (
        qops.qmatmul_acc(e1_mat, cols0.T, _zi(qp, 25), z_in).astype(jnp.float32)
        * (qp[24] * qp[0])
    )
    gb1 = jnp.sum(de1, axis=1).astype(jnp.float32) * qp[24]

    # telemetry for the Rust-side observers
    err_minmax = jnp.stack(
        [
            jnp.stack([jnp.min(e5_f), jnp.max(e5_f)]),
            jnp.stack([e4_f_lo, e4_f_hi]),
            jnp.stack([e3_lo, e3_hi]),
            jnp.stack([e1_lo, e1_hi]),
        ]
    )
    sat = jnp.stack(
        [
            jnp.mean((a1 == 255).astype(jnp.float32)),
            jnp.mean((a2 == 255).astype(jnp.float32)),
            jnp.mean((a4 == 255).astype(jnp.float32)),
            jnp.mean(((a5 == 255) | (a5 == 0)).astype(jnp.float32)),
        ]
    )

    return (loss, logits, gw1, gb1, gw2, gb2, gw4, gb4, gw5, gb5, err_minmax, sat)


# --------------------------------------------------------------------------
# float32 reference configuration
# --------------------------------------------------------------------------


def _float_forward(params, x):
    w1, b1, w2, b2, w4, b4, w5, b5 = params
    cols0, (oh1, ow1) = qops.im2col(x, 3, 3, 2, 1, 1, jnp.float32(0.0))
    a1 = jnp.maximum(w1 @ cols0 + b1[:, None], 0.0).reshape(C1, oh1, ow1)
    cols1, (oh2, ow2) = qops.im2col(a1, 3, 3, 2, 1, 1, jnp.float32(0.0))
    a2 = jnp.maximum(w2 @ cols1 + b2[:, None], 0.0).reshape(C2, oh2, ow2)
    a2c = a2[:, :6, :6].reshape(C2, 3, 2, 3, 2).transpose(0, 1, 3, 2, 4).reshape(C2, 9, 4)
    a3 = jnp.max(a2c, axis=-1).reshape(FLAT)
    a4 = jnp.maximum(w4 @ a3 + b4, 0.0)
    return w5 @ a4 + b5


def float_train_step(x, onehot, w1, b1, w2, b2, w4, b4, w5, b5):
    """Float32 train step (reference configuration) via jax.grad."""
    params = (w1, b1, w2, b2, w4, b4, w5, b5)

    def loss_fn(p):
        logits = _float_forward(p, x)
        lmax = jnp.max(logits)
        lse = lmax + jnp.log(jnp.sum(jnp.exp(logits - lmax)))
        return lse - jnp.sum(logits * onehot), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    return (loss, logits) + tuple(grads)


def fqt_example_args():
    """Example (shape, dtype) pytree used for lowering the FQT step."""
    u8 = jnp.uint8
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    return (
        sds(IN_SHAPE, u8),
        sds((N_CLASSES,), f32),
        sds((C1, 9), u8),
        sds((C1,), f32),
        sds((C2, C1 * 9), u8),
        sds((C2,), f32),
        sds((FC1, FLAT), u8),
        sds((FC1,), f32),
        sds((N_CLASSES, FC1), u8),
        sds((N_CLASSES,), f32),
        sds((QP_LEN,), f32),
    )


def float_example_args():
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    return (
        sds(IN_SHAPE, f32),
        sds((N_CLASSES,), f32),
        sds((C1, 9), f32),
        sds((C1,), f32),
        sds((C2, C1 * 9), f32),
        sds((C2,), f32),
        sds((FC1, FLAT), f32),
        sds((FC1,), f32),
        sds((N_CLASSES, FC1), f32),
        sds((N_CLASSES,), f32),
    )


def qmatmul_demo(a_q, b_q, qp):
    """Tiny artifact for the Rust<->Pallas bit-exactness cross-check:
    qmatmul with runtime qparams (qp = [za, zb, mult, zo])."""
    za = qp[0].astype(jnp.int32)
    zb = qp[1].astype(jnp.int32)
    zo = qp[3].astype(jnp.int32)
    y = qops.qmatmul(a_q, b_q, za, zb, qp[2], zo, relu=False)
    acc = qops.qmatmul_acc(a_q, b_q, za, zb)
    return (y, acc)


def qmatmul_demo_args(m=16, k=32, n=8):
    u8 = jnp.uint8
    sds = jax.ShapeDtypeStruct
    return (sds((m, k), u8), sds((k, n), u8), sds((4,), jnp.float32))
