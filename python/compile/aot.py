"""AOT lowering: JAX train-step graphs -> HLO *text* artifacts + JSON
manifests for the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
XLA 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md). Lowered with ``return_tuple=True``; the
Rust side unwraps the tuple per the manifest.

Run once via ``make artifacts``; never on the training path.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(args, out_tree):
    def one(sds):
        return {"dtype": str(sds.dtype), "shape": list(sds.shape)}

    return {
        "inputs": [one(a) for a in args],
        "outputs": [one(o) for o in out_tree],
    }


ARTIFACTS = {
    "qmatmul_demo": (model.qmatmul_demo, model.qmatmul_demo_args),
    "mnist_cnn_uint8_train": (model.fqt_train_step, model.fqt_example_args),
    "mnist_cnn_float32_train": (model.float_train_step, model.float_example_args),
}


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, args_fn) in ARTIFACTS.items():
        args = args_fn()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        out_shapes = jax.eval_shape(fn, *args)
        if not isinstance(out_shapes, tuple):
            out_shapes = (out_shapes,)
        manifest = spec_json(args, out_shapes)
        manifest["name"] = name
        manifest["hlo_sha256"] = hashlib.sha256(text.encode()).hexdigest()
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"wrote {name}: {len(text)} chars, "
              f"{len(manifest['inputs'])} inputs -> {len(manifest['outputs'])} outputs")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = p.parse_args()
    build(args.out)


if __name__ == "__main__":
    sys.exit(main())
