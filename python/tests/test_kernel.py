"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and quantization parameters; equality must be
exact (integer arithmetic + a shared deterministic rounding rule)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qops, ref

jax.config.update("jax_platform_name", "cpu")


def rand_u8(rng, shape):
    return jnp.asarray(rng.integers(0, 256, size=shape, dtype=np.uint8))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 64),
    n=st.integers(1, 160),
    za=st.integers(0, 255),
    zb=st.integers(0, 255),
    zo=st.integers(0, 255),
    mult=st.floats(1e-4, 0.5, allow_nan=False),
    relu=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_qmatmul_matches_ref(m, k, n, za, zb, zo, mult, relu, seed):
    rng = np.random.default_rng(seed)
    a = rand_u8(rng, (m, k))
    b = rand_u8(rng, (k, n))
    got = qops.qmatmul(a, b, za, zb, mult, zo, relu=relu)
    want = ref.qmatmul_ref(a, b, za, zb, mult, zo, relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 48),
    n=st.integers(1, 140),
    za=st.integers(0, 255),
    zb=st.integers(0, 255),
    seed=st.integers(0, 2**31),
)
def test_qmatmul_acc_matches_ref(m, k, n, za, zb, seed):
    rng = np.random.default_rng(seed)
    a = rand_u8(rng, (m, k))
    b = rand_u8(rng, (k, n))
    got = qops.qmatmul_acc(a, b, za, zb)
    want = ref.qmatmul_acc_ref(a, b, za, zb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_round_half_away_matches_rust_round():
    xs = jnp.asarray([0.5, 1.5, 2.5, -0.5, -1.5, -2.5, 0.49, -0.49])
    got = qops.round_half_away(xs)
    # Rust f32::round: half away from zero
    want = jnp.asarray([1.0, 2.0, 3.0, -1.0, -2.0, -3.0, 0.0, -0.0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_relu_clamps_at_zero_point():
    rng = np.random.default_rng(0)
    a = rand_u8(rng, (8, 16))
    b = rand_u8(rng, (16, 8))
    y = qops.qmatmul(a, b, 128, 128, 0.001, 100, relu=True)
    assert int(np.asarray(y).min()) >= 100


def test_im2col_col2im_adjoint():
    # <im2col(x), y> == <x, col2im(y)> — the defining adjoint property that
    # makes the conv backward correct.
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 9, 9)).astype(np.float32))
    cols, (oh, ow) = qops.im2col(x, 3, 3, 2, 1, 1, jnp.float32(0))
    y = jnp.asarray(rng.normal(size=cols.shape).astype(np.float32))
    lhs = float(jnp.sum(cols * y))
    back = qops.col2im(y, 3, 9, 9, 3, 3, 2, 1, 1)
    rhs = float(jnp.sum(x * back))
    assert abs(lhs - rhs) < 1e-3 * max(1.0, abs(lhs))


def test_im2col_pads_with_zero_point():
    x = jnp.full((1, 4, 4), 7, jnp.uint8)
    cols, _ = qops.im2col(x, 3, 3, 1, 1, 1, jnp.uint8(9))
    vals = set(np.asarray(cols).ravel().tolist())
    assert vals == {7, 9}


def test_qmatmul_shapes_not_multiple_of_block():
    # deliberately awkward shapes straddling the BLOCK_M/BLOCK_N tiles
    rng = np.random.default_rng(2)
    a = rand_u8(rng, (33, 7))
    b = rand_u8(rng, (7, 129))
    got = qops.qmatmul(a, b, 1, 2, 0.01, 3)
    want = ref.qmatmul_ref(a, b, 1, 2, 0.01, 3)
    assert got.shape == (33, 129)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_dequantize_ref_roundtrip():
    x = jnp.asarray(np.linspace(-2, 2, 101, dtype=np.float32))
    q = ref.quantize_ref(x, 4.0 / 255.0, 128)
    back = ref.dequantize_ref(q, 4.0 / 255.0, 128)
    assert float(jnp.max(jnp.abs(back - x))) <= 0.5 * 4.0 / 255.0 + 1e-6
