"""L2 correctness: the quantized train-step graph vs the float reference.

Checks shapes, gradient signs/correlation between the FQT and float paths,
and that a few steps of FQT descent reduce the loss — the Python-side
mirror of the Rust integration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_state(seed=0):
    rng = np.random.default_rng(seed)
    f32 = np.float32

    def he(shape, fan_in):
        return rng.normal(0, np.sqrt(2.0 / fan_in), size=shape).astype(f32)

    w1 = he((model.C1, 9), 9)
    w2 = he((model.C2, model.C1 * 9), model.C1 * 9)
    w4 = he((model.FC1, model.FLAT), model.FLAT)
    w5 = he((model.N_CLASSES, model.FC1), model.FC1)
    b = [np.zeros(s, f32) for s in (model.C1, model.C2, model.FC1, model.N_CLASSES)]
    return (w1, b[0], w2, b[1], w4, b[2], w5, b[3])


def quantize_state(ws, qp_act):
    """PTQ-style quantization of the float state; returns quantized weights
    plus the packed qparams vector."""
    w1, b1, w2, b2, w4, b4, w5, b5 = ws
    qp = np.zeros(model.QP_LEN, np.float32)

    def qparams(x):
        lo, hi = min(float(x.min()), 0.0), max(float(x.max()), 0.0)
        s = max(hi - lo, 1e-8) / 255.0
        z = int(round(-lo / s))
        return s, z

    qp[0], qp[1] = qp_act["in"]
    out_w = []
    for i, w in enumerate((w1, w2, w4, w5)):
        s, z = qparams(w)
        qp[2 + 4 * i], qp[3 + 4 * i] = s, z
        out_w.append(np.asarray(ref.quantize_ref(jnp.asarray(w), s, z)))
    qp[4], qp[5] = qp_act["a1"]
    qp[8], qp[9] = qp_act["a2"]
    qp[12], qp[13] = qp_act["a4"]
    qp[16], qp[17] = qp_act["a5"]
    # error ranges: head error in [-1, 1]; deeper errors start wider
    for base, (s, z) in zip((18, 20, 22, 24), [(2.0 / 255, 128)] * 4):
        qp[base], qp[base + 1] = s, z
    return out_w, jnp.asarray(qp)


def default_act_qp():
    return {
        "in": (4.0 / 255, 128),
        "a1": (4.0 / 255, 0),
        "a2": (6.0 / 255, 0),
        "a4": (6.0 / 255, 0),
        "a5": (8.0 / 255, 128),
    }


def sample(seed, label):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 0.5, size=model.IN_SHAPE).astype(np.float32) + 0.3 * label
    onehot = np.zeros(model.N_CLASSES, np.float32)
    onehot[label] = 1.0
    return x, jnp.asarray(onehot)


def test_float_step_shapes_and_grad_check():
    ws = make_state(1)
    x, onehot = sample(2, 3)
    out = model.float_train_step(jnp.asarray(x), onehot, *[jnp.asarray(w) for w in ws])
    loss, logits = out[0], out[1]
    grads = out[2:]
    assert logits.shape == (model.N_CLASSES,)
    assert float(loss) > 0
    shapes = [w.shape for w in ws]
    assert [g.shape for g in grads] == shapes
    # numeric gradient spot-check on w5
    eps = 1e-3
    i, j = 3, 10
    wp = [w.copy() for w in ws]
    wp[6][i, j] += eps
    wm = [w.copy() for w in ws]
    wm[6][i, j] -= eps
    lp = model.float_train_step(jnp.asarray(x), onehot, *[jnp.asarray(w) for w in wp])[0]
    lm = model.float_train_step(jnp.asarray(x), onehot, *[jnp.asarray(w) for w in wm])[0]
    num = (float(lp) - float(lm)) / (2 * eps)
    ana = float(grads[6][i, j])
    assert abs(num - ana) < 1e-2, (num, ana)


def test_fqt_step_shapes():
    ws = make_state(3)
    qw, qp = quantize_state(ws, default_act_qp())
    x, onehot = sample(4, 1)
    xq = ref.quantize_ref(jnp.asarray(x), float(qp[0]), int(qp[1]))
    out = model.fqt_train_step(
        xq, onehot,
        jnp.asarray(qw[0]), jnp.asarray(ws[1]),
        jnp.asarray(qw[1]), jnp.asarray(ws[3]),
        jnp.asarray(qw[2]), jnp.asarray(ws[5]),
        jnp.asarray(qw[3]), jnp.asarray(ws[7]),
        qp,
    )
    loss, logits, gw1, gb1, gw2, gb2, gw4, gb4, gw5, gb5, mm, sat = out
    assert logits.shape == (10,)
    assert gw1.shape == (model.C1, 9)
    assert gw2.shape == (model.C2, model.C1 * 9)
    assert gw4.shape == (model.FC1, model.FLAT)
    assert gw5.shape == (model.N_CLASSES, model.FC1)
    assert mm.shape == (4, 2)
    assert sat.shape == (4,)
    assert float(loss) > 0
    # head error minmax brackets zero
    assert float(mm[0, 0]) <= 0.0 <= float(mm[0, 1])


def test_fqt_head_gradient_correlates_with_float():
    """The quantized head gradient must point the same way as the float
    gradient (it is the same outer product up to quantization noise)."""
    ws = make_state(5)
    qw, qp = quantize_state(ws, default_act_qp())
    x, onehot = sample(6, 7)
    xq = ref.quantize_ref(jnp.asarray(x), float(qp[0]), int(qp[1]))
    fq = model.fqt_train_step(
        xq, onehot,
        jnp.asarray(qw[0]), jnp.asarray(ws[1]),
        jnp.asarray(qw[1]), jnp.asarray(ws[3]),
        jnp.asarray(qw[2]), jnp.asarray(ws[5]),
        jnp.asarray(qw[3]), jnp.asarray(ws[7]),
        qp,
    )
    # float gradients on the dequantized weights (same operating point)
    dws = [np.asarray(ref.dequantize_ref(jnp.asarray(qw[i]), float(qp[2 + 4 * i]), int(qp[3 + 4 * i]))) for i in range(4)]
    fl = model.float_train_step(
        jnp.asarray(x), onehot,
        jnp.asarray(dws[0]), jnp.asarray(ws[1]),
        jnp.asarray(dws[1]), jnp.asarray(ws[3]),
        jnp.asarray(dws[2]), jnp.asarray(ws[5]),
        jnp.asarray(dws[3]), jnp.asarray(ws[7]),
    )
    g_q = np.asarray(fq[8]).ravel()  # gw5
    g_f = np.asarray(fl[8]).ravel()
    denom = np.linalg.norm(g_q) * np.linalg.norm(g_f)
    assert denom > 0
    corr = float(g_q @ g_f / denom)
    assert corr > 0.7, corr


def test_fqt_descent_reduces_loss():
    """A few SGD steps on the quantized gradients must reduce the loss —
    end-to-end sanity of the backward graph."""
    ws = [w.copy() for w in make_state(7)]
    act = default_act_qp()
    x, onehot = sample(8, 2)
    lr = 0.05
    losses = []
    for _ in range(6):
        qw, qp = quantize_state(ws, act)
        xq = ref.quantize_ref(jnp.asarray(x), float(qp[0]), int(qp[1]))
        out = model.fqt_train_step(
            xq, onehot,
            jnp.asarray(qw[0]), jnp.asarray(ws[1]),
            jnp.asarray(qw[1]), jnp.asarray(ws[3]),
            jnp.asarray(qw[2]), jnp.asarray(ws[5]),
            jnp.asarray(qw[3]), jnp.asarray(ws[7]),
            qp,
        )
        losses.append(float(out[0]))
        grads = out[2:10]
        # float-space descent on dequantized weights (Eq. 5), requantized
        # on the next loop iteration by quantize_state (Eqs. 6-7)
        for i, wi in enumerate((0, 2, 4, 6)):
            dw = np.asarray(ref.dequantize_ref(jnp.asarray(qw[i]), float(qp[2 + 4 * i]), int(qp[3 + 4 * i])))
            ws[wi] = (dw - lr * np.asarray(grads[2 * i])).astype(np.float32)
            ws[wi + 1] = (ws[wi + 1] - lr * np.asarray(grads[2 * i + 1])).astype(np.float32)
    assert losses[-1] < losses[0], losses
