//! On-device transfer learning with dynamic sparse gradient updates — the
//! §IV-A/IV-C scenario on the flowers stand-in: pretrain MbedNet on the
//! source domain, deploy fully quantized, reset the last five layers, then
//! retrain on-device under three gradient update rates (λ_min ∈ {1.0, 0.5,
//! 0.1}) and report accuracy plus backward-pass savings.

use tinytrain::data::{spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{self, Knobs};
use tinytrain::util::bench::fmt_duration;

fn main() {
    let mut spec = spec_by_name("flowers").expect("dataset registry");
    spec.reduced_shape = [3, 24, 24]; // keep the example interactive
    let knobs = Knobs::from_env();
    let seed = 7;

    println!("== transfer learning on the {} stand-in (MbedNet, uint8 FQT) ==", spec.name);
    let src = Domain::new(&spec, spec.reduced_shape, seed);
    let def = harness::mbednet_for(&spec, &spec.reduced_shape);
    println!("pretraining feature extractor on the source domain…");
    let (fp, base) = harness::pretrain(&def, &src, knobs.epochs, &knobs, seed + 1);
    println!("source-domain baseline accuracy: {base:.3}\n");

    let dev = device::imxrt1062();
    println!(
        "{:<10} {:>9} {:>12} {:>14} {:>12}",
        "λ_min", "test_acc", "kept_structs", "bwd µs/sample", "bwd speedup"
    );
    let mut dense_bwd = None;
    for &lambda in &[1.0f32, 0.5, 0.1] {
        let mut scen = harness::tl_scenario(&spec, DnnConfig::Uint8, &fp, &src, &knobs, seed + 2);
        let rep = harness::run_tl(&mut scen, lambda, &knobs, seed + 3);
        let (_, bwd) = harness::step_costs(&mut scen.model, &scen.train, &dev, lambda);
        let base_bwd = *dense_bwd.get_or_insert(bwd.seconds);
        println!(
            "{:<10} {:>9.3} {:>11.1}% {:>14} {:>11.2}x",
            lambda,
            rep.final_test_acc(),
            rep.kept_fraction * 100.0,
            fmt_duration(bwd.seconds),
            base_bwd / bwd.seconds
        );
    }
    println!("\n(dense λ=1.0 is the Fig. 4 configuration; λ=0.5/0.1 are Fig. 6)");
}
