//! Streaming coordinator scenario — the paper's motivating deployment
//! (§I): an MCU classifies arriving samples with zero downtime while
//! adapting in place, then the input domain shifts mid-stream and the
//! model recovers by continuing to train on the new distribution.

use tinytrain::coordinator::{stream::SampleStream, Coordinator, CoordinatorConfig};
use tinytrain::data::{spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::exec::{calibrate, FloatParams, NativeModel};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::train::fqt::FqtSgd;
use tinytrain::train::loop_::Sparsity;
use tinytrain::train::sparse::DynamicSparse;
use tinytrain::util::bench::{env_usize, fmt_duration};
use tinytrain::util::prng::Pcg32;

fn main() {
    let mut spec = spec_by_name("cifar10").expect("dataset registry");
    spec.reduced_shape = [3, 16, 16];
    let n = env_usize("TT_STREAM_SAMPLES", 300);
    let seed = 11;

    println!("== streaming on-device adaptation with a mid-stream domain shift ==\n");
    let mut rng = Pcg32::seeded(seed);
    let shape = spec.reduced_shape;
    let dom_a = Domain::new(&spec, shape, seed);
    let dom_b = dom_a.shifted(seed ^ 0xFF);

    let def = models::mnist_cnn(&shape, spec.classes);
    let fp = FloatParams::init(&def, &mut rng);
    let (cal, _) = dom_a.splits(2, 0, &mut rng);
    let calib = calibrate(&def, &fp, &cal.xs);
    let model = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);

    let mut opt = FqtSgd::new(&model, 0.01, 8);
    let sparsity = Sparsity::Dynamic(DynamicSparse::new(0.5, 1.0));
    let mut coord = Coordinator::builder(model, device::imxrt1062(), &mut opt)
        .sparsity(sparsity)
        .config(
            CoordinatorConfig::builder()
                .replay_capacity(48)
                .max_steps_per_gap(3)
                .warmup_samples(8)
                .build(),
        )
        .seed(seed)
        .build();

    // phase 1: domain A only
    println!("phase 1: {} arrivals from domain A @10 Hz", n / 2);
    let mut s1 = SampleStream::new(&dom_a, n / 2, 0.1, seed + 1);
    coord.run(&mut s1);
    let p1 = coord.telemetry.clone();
    println!(
        "  online acc {:.3} | {} train steps | util {:.1}% | {:.2} J",
        p1.online_accuracy(),
        p1.train_steps,
        p1.utilization() * 100.0,
        p1.energy_j
    );

    // phase 2: domain shifts to B — accuracy dips, then training recovers
    coord.telemetry = Default::default();
    println!("phase 2: domain SHIFTS to B — {} more arrivals", n / 2);
    let mut s2 = SampleStream::new(&dom_b, n / 2, 0.1, seed + 2);
    coord.run(&mut s2);
    let p2 = coord.telemetry.clone();
    println!(
        "  online acc {:.3} | {} train steps | util {:.1}% | {:.2} J",
        p2.online_accuracy(),
        p2.train_steps,
        p2.utilization() * 100.0,
        p2.energy_j
    );

    // phase 3: continued exposure to B — in-place adaptation pays off
    coord.telemetry = Default::default();
    println!("phase 3: {} more arrivals from B (adapted)", n / 2);
    let mut s3 = SampleStream::new(&dom_b, n / 2, 0.1, seed + 3);
    coord.run(&mut s3);
    let p3 = coord.telemetry.clone();
    println!(
        "  online acc {:.3} | {} train steps | busy {} of {}",
        p3.online_accuracy(),
        p3.train_steps,
        fmt_duration(p3.busy_s),
        fmt_duration(p3.elapsed_s)
    );

    println!(
        "\nrecovery after shift: {:.3} -> {:.3} (domain B online accuracy)",
        p2.online_accuracy(),
        p3.online_accuracy()
    );
}
