//! End-to-end quickstart — **the full three-layer stack on a real small
//! workload**: fully quantized training of the §IV-D CNN on the
//! EMNIST-Digits stand-in, executed through the AOT Pallas/JAX HLO
//! artifact via PJRT (Python is not involved at runtime), with the FQT
//! optimizer (Eqs. 5–8), error observers and activation-range adaptation
//! running in Rust.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`
//! The loss curve and final accuracies are recorded in EXPERIMENTS.md.

use tinytrain::data::{spec_by_name, Domain};
use tinytrain::runtime::{artifacts_dir, xla_trainer::load_fqt_trainer};
use tinytrain::util::bench::env_usize;
use tinytrain::util::prng::Pcg32;

fn main() -> anyhow::Result<()> {
    let spec = spec_by_name("emnist-digits").expect("dataset registry");
    let epochs = env_usize("TT_EPOCHS", 8);
    let per_class = env_usize("TT_TRAIN_PC", 6);
    let seed = 42;

    println!("== tinytrain quickstart: FQT via AOT HLO artifact (PJRT) ==");
    let mut trainer = load_fqt_trainer(&artifacts_dir(), (-2.0, 4.0), 0.01, 8, seed)?;
    println!("artifact loaded; uint8 weights initialized\n");

    let dom = Domain::new(&spec, [1, 28, 28], seed);
    let mut rng = Pcg32::seeded(seed);
    let (train, test) = dom.splits(per_class, per_class / 2, &mut rng);
    println!(
        "dataset: {} stand-in — {} train / {} test samples, {} classes",
        spec.name,
        train.len(),
        test.len(),
        spec.classes
    );

    let acc0 = trainer.evaluate(&test.xs, &test.ys)?;
    println!("initial test accuracy: {acc0:.3} (chance = {:.3})\n", 1.0 / spec.classes as f32);
    println!("{:<7} {:>10} {:>10} {:>10}", "epoch", "loss", "train_acc", "test_acc");

    for ep in 0..epochs {
        let order = rng.permutation(train.len());
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        for &i in &order {
            let (loss, pred) = trainer.train_step(&train.xs[i], train.ys[i])?;
            loss_sum += loss;
            if pred == train.ys[i] {
                correct += 1;
            }
        }
        trainer.finish();
        let test_acc = trainer.evaluate(&test.xs, &test.ys)?;
        println!(
            "{:<7} {:>10.4} {:>10.3} {:>10.3}",
            ep,
            loss_sum / train.len() as f32,
            correct as f32 / train.len() as f32,
            test_acc
        );
    }

    let acc1 = trainer.evaluate(&test.xs, &test.ys)?;
    println!("\nfinal test accuracy: {acc1:.3} (started at {acc0:.3})");
    println!("train steps executed through PJRT: {}", trainer.steps);
    for i in 0..4 {
        let qp = trainer.layer_qp(i);
        println!("layer {i} weight range adapted to scale={:.5} zp={}", qp.scale, qp.zero_point);
    }
    anyhow::ensure!(acc1 > acc0, "training must improve over the initial state");
    println!("\nquickstart OK");
    Ok(())
}
