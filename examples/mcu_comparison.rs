//! Cross-MCU deployment study (§IV-B flavor): for one dataset stand-in,
//! report per-sample training latency, energy, and the memory plan across
//! the three Tab. II devices and the three DNN configurations — including
//! which deployments do not fit (the paper's red dashed lines).

use tinytrain::data::spec_by_name;
use tinytrain::device;
use tinytrain::graph::{models, DnnConfig};
use tinytrain::harness::{self, Knobs};
use tinytrain::memplan;
use tinytrain::util::bench::fmt_duration;

fn main() {
    let spec = spec_by_name("cwru").expect("dataset registry");
    let knobs = Knobs::from_env();

    println!("== {} stand-in across MCUs (MbedNet transfer learning) ==\n", spec.name);

    // memory at the paper's native shape
    println!("{:<10} {:>12} {:>12} {:>10}  fits", "config", "feat RAM", "w+g RAM", "Flash");
    for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
        let mem = harness::tl_memory(&spec, cfg);
        let fits: Vec<String> = device::all_devices()
            .iter()
            .map(|d| {
                format!("{}:{}", d.name, if d.fits(mem.total_ram(), mem.flash) { "y" } else { "N" })
            })
            .collect();
        println!(
            "{:<10} {:>11}B {:>11}B {:>9}B  {}",
            cfg.name(),
            mem.feature_ram,
            mem.weight_ram,
            mem.flash,
            fits.join(" ")
        );
    }

    // latency + energy per training sample (reduced-shape execution for op
    // counts, device cost model for the pricing)
    println!("\n{:<11} {:<10} {:>13} {:>13} {:>12}", "device", "config", "fwd/sample", "bwd/sample", "energy");
    let src = tinytrain::data::Domain::new(&spec, spec.reduced_shape, 3);
    let def = harness::mbednet_for(&spec, &spec.reduced_shape);
    let (fp, _) = harness::pretrain(&def, &src, 1, &knobs, 4);
    for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
        let mut scen = harness::tl_scenario(&spec, cfg, &fp, &src, &knobs, 5);
        for dev in device::all_devices() {
            let (f, b) = harness::step_costs(&mut scen.model, &scen.train, &dev, 1.0);
            println!(
                "{:<11} {:<10} {:>13} {:>13} {:>9.2} mJ",
                dev.name,
                cfg.name(),
                fmt_duration(f.seconds),
                fmt_duration(b.seconds),
                (f.joules + b.joules) * 1e3
            );
        }
    }

    // the in-place property: training keeps inference available — compare
    // inference-only RAM vs training RAM for the uint8 config
    let def_full = models::mbednet(&spec.paper_shape, spec.classes);
    let inf = memplan::plan(&def_full, DnnConfig::Uint8, false);
    let tr = memplan::plan(&def_full, DnnConfig::Uint8, true);
    println!(
        "\ntraining RAM overhead vs inference-only: {} B -> {} B ({:.2}x)",
        inf.total_ram(),
        tr.total_ram(),
        tr.total_ram() as f32 / inf.total_ram() as f32
    );
}
